"""Continuous-batching scheduler over the jitted prefill/decode entry points.

Two pool layouts serve the same masked decode step (DESIGN.md §7):

  dense (default) — one preallocated slot-pool KV cache (``Model.init_cache``
  layout, batch dim = ``num_slots``): every slot owns ``cache_len`` rows of
  every leaf regardless of how many tokens it actually holds.

  paged (``paged=True``) — fixed-size blocks in per-leaf arenas
  ``[layers, num_blocks + 1, block, ...]`` plus a per-slot block table;
  admission reserves ``ceil((prompt + max_new) / block)`` blocks from a
  refcounted free list (``serving.paging.BlockAllocator``), so admission is
  *by memory, not slot count*, a 16-token request holds one block where a
  4096-token request holds 64, and requests whose prompt prefix hashes to
  already-resident blocks share them copy-on-write and skip the covered
  prefill compute entirely (``prefill_resume``).

Lifecycle of a request:

  submit() ─→ queue ─→ admission (free slot + free blocks): bucketed
  single-request jitted prefill (or suffix-only resume prefill on a prefix
  hit) + a donated splice/scatter into the pool ─→ masked decode steps
  until EOS or the token budget ─→ retirement frees the slot and decrefs
  its blocks (published prefix blocks stay cached until evicted LRU).

The first generated token comes from the prefill logits (same contract as
``engine.generate``).  Sampling parameters ride on the ``Request``
(``temperature``, ``top_k``); each sampled request draws from its own PRNG
stream (``fold_in(base_key, uid)``), split once per *sampled* token —
greedy requests never consume randomness, so temperature=0 results are
key-independent.

Failure paths thread through the same lifecycle (DESIGN.md §11):

  deadlines — ``Request.deadline_s`` (TTL from submit) retires overdue
  work at the next ``step()`` with ``finish_reason="deadline"`` (partial
  tokens included) and frees its slot/blocks; ``cancel(uid)`` does the
  same on demand with ``finish_reason="cancelled"``.

  preemption — when the best queued request outranks the least important
  active slot (``Request.priority`` first, then submit order), the victim
  is evicted: its full blocks are published to the prefix registry, its
  blocks decrefed, and its partial state requeued for recompute; on
  re-admission the resume prompt (prompt + generated so far) reacquires
  the published blocks, so only the tail is recomputed.  Preemption is
  strictly rank-decreasing (never an equal-or-better victim), so the
  highest-ranked request in the system always runs to completion — no
  livelock.

  live resize — ``resize(num_slots=…, num_blocks=…)`` grows pools
  immediately; shrinks fence the excess and defer until the draining
  slots/blocks empty, never dropping in-flight requests.

  snapshot/restore — ``snapshot()`` captures scheduler + allocator +
  request + pool state host-side; ``Scheduler.from_snapshot`` resumes
  mid-stream with bit-identical surviving token streams (the serving twin
  of ``training/fault.py``).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models.model import Model
from repro.models.transformer import block_cache_kinds
from .paging import BlockAllocator, chain_hashes, logical_blocks

NEG_INF = -1e30


@dataclasses.dataclass
class Request:
    """One generation request.  ``inputs`` are the per-request model inputs
    with leading batch dim 1 (at minimum ``tokens [1, S]``; multimodal
    frontends add their embedding arrays).  ``temperature``/``top_k`` are
    per-request sampling parameters: temperature 0 is greedy (consumes no
    PRNG), top_k 0 disables the top-k filter.  ``priority`` orders
    admission and preemption (higher wins; ties go to the older request);
    ``deadline_s`` is a TTL from submit after which the request is retired
    with ``finish_reason="deadline"``.  ``on_token`` (optional callable
    ``(uid, index, token, logprob)``) streams each generated token as it is
    picked — the async serving front-end's hook; it is host-side state and
    is dropped from snapshots/journals (reconnecting clients replay from
    the server's buffers instead)."""
    uid: int
    inputs: dict
    max_new_tokens: int
    key: jax.Array | None = None          # per-request sampling stream
    temperature: float = 0.0
    top_k: int = 0
    priority: int = 0
    deadline_s: float | None = None
    on_token: object | None = dataclasses.field(default=None, repr=False,
                                                compare=False)


@dataclasses.dataclass
class FinishedRequest:
    uid: int
    tokens: np.ndarray                    # [n_generated] int32
    logprobs: np.ndarray                  # [n_generated] float32
    finish_reason: str          # "eos" | "length" | "deadline" | "cancelled"
    prompt_len: int
    submit_time: float                    # perf_counter at submit()
    finish_time: float                    # perf_counter at retirement
    first_token_time: float | None = None  # perf_counter at first token


@dataclasses.dataclass
class _Resume:
    """Partial generation state of a preempted request: everything needed
    to continue its token stream bit-identically after re-admission."""
    tokens: list[int]
    logprobs: list[float]
    key: jax.Array | None                 # PRNG stream state at preemption
    last_tok: int
    first_token_time: float | None = None


@dataclasses.dataclass
class _Queued:
    req: Request
    prompt_len: int
    submit_time: float
    deadline: float | None = None         # absolute (scheduler clock)
    resume: _Resume | None = None         # set on preempted re-queues


@dataclasses.dataclass
class _Slot:
    uid: int
    req: Request                          # original request (preemption
    max_new: int                          # rebuilds the queue entry)
    key: jax.Array | None
    prompt_len: int
    submit_time: float
    temperature: float = 0.0
    top_k: int = 0
    priority: int = 0
    deadline: float | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    logprobs: list[float] = dataclasses.field(default_factory=list)
    last_tok: int = 0
    first_token_time: float | None = None
    # chunked-prefill state machine: ``prefill_pos`` is None once the
    # prompt is fully prefilled (slot is decoding); while prefilling it
    # counts prompt tokens already processed.  ``prefill_toks`` is the
    # effective prompt (original + resume tokens) and ``prefill_table``
    # the slot's sentinel-padded block-table row (paged pools).
    prefill_pos: int | None = None
    prefill_toks: np.ndarray | None = dataclasses.field(
        default=None, repr=False)
    prefill_table: np.ndarray | None = dataclasses.field(
        default=None, repr=False)


class Scheduler:
    """Continuous-batching loop: ``submit()`` any time, ``step()`` advances
    every active slot by one token and admits queued requests into freed
    slots, ``run()`` drains."""

    def __init__(self, model: Model, params, num_slots: int, cache_len: int,
                 *, eos_id: int | None = None, key: jax.Array | None = None,
                 paged: bool = False, block_size: int = 64,
                 num_blocks: int | None = None, prefix_cache: bool = True,
                 bucket_prompts: bool = True, preempt: bool = True,
                 clock=None, mesh=None, chunk_prefill: bool = False,
                 chunk_size: int = 64, prefill_budget: int | None = None):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        # Chunked prefill: prompts are processed ``chunk_size`` tokens at a
        # time INSIDE the fused decode step (one traced program per
        # (lanes, chunk) shape) instead of a monolithic admission prefill.
        # ``prefill_budget`` caps prefill tokens per step: the step runs
        # floor(budget / chunk_size) chunk lanes alongside the B decode
        # rows, trading TTFT of admitting requests against inter-token
        # latency of running ones.
        self.chunk_prefill = bool(chunk_prefill)
        self.chunk_size = int(chunk_size)
        budget = self.chunk_size if prefill_budget is None else int(prefill_budget)
        self.prefill_budget = budget
        self.chunk_lanes = max(1, budget // max(1, self.chunk_size))
        self.prefill_chunks = 0           # chunk lanes executed
        if self.chunk_prefill:
            if self.chunk_size < 1:
                raise ValueError("chunk_size must be >= 1")
            if not model.supports_chunked_prefill:
                raise ValueError(
                    "model does not support chunked prefill (encoder-decoder "
                    "and frontend models prefill monolithically)")
        self.model = model
        self.mesh = mesh
        if mesh is not None:
            # one placement decision, made here: params land sharded per
            # DESIGN.md §14 and every jitted entry point (prefill, masked
            # decode, splice, resume) is partitioned by GSPMD from its
            # operands — the traced programs are unchanged, so one decode
            # step stays one executable, collectives compiled in.
            params = jax.device_put(params, shd.serve_param_shardings(
                model.param_specs(), params, mesh))
        self.params = params
        self.preempt = preempt
        # injectable clock (deadlines, latency stamps): tests and the
        # fault harness drive a virtual clock for determinism
        self._now = clock if clock is not None else time.perf_counter
        # Touch the model's PlanBook up front: every TT layer's execution
        # plan is resolved (or confirmed resolved) here, outside any jit
        # trace, so admission prefills and the masked decode step perform
        # ZERO plan resolutions — asserted by tests via
        # kernels.plan.plan_resolutions() and the serve.py CI smoke.
        model.plan_book
        self.num_slots = num_slots
        self.eos_id = eos_id
        self.base_key = key
        self.paged = paged
        self.bucket_prompts = bucket_prompts
        if paged:
            self.block = block_size
            self.max_blocks = logical_blocks(cache_len, block_size)
            # the pool's logical length is block-aligned so prefilled rows
            # scatter into whole blocks
            self.cache_len = self.max_blocks * block_size
            self.num_blocks = (num_blocks if num_blocks is not None
                               else num_slots * self.max_blocks)
            self.allocator = BlockAllocator(self.num_blocks, block_size)
            self.prefix_cache = prefix_cache and model.supports_prefix_reuse
            self._slot_blocks: list[list[int] | None] = [None] * num_slots
            self.block_hwm = 0                # live blocks high-water mark
            self.prefix_hit_tokens = 0        # prompt tokens found resident
            self.prefix_prompt_tokens = 0     # prompt tokens seen (paged)
            self.prefill_tokens_skipped = 0   # prefill compute avoided
        else:
            self.cache_len = cache_len
        self.queue: deque[_Queued] = deque()
        self.slots: list[_Slot | None] = [None] * num_slots
        self.cache = None                 # pool; built from first prefill
        self.finished: list[FinishedRequest] = []
        self.steps_run = 0                # decode steps executed
        self.tokens_out = 0               # total generated tokens
        self.preemptions = 0              # slots evicted + requeued
        self.cancelled = 0                # requests cancelled via cancel()
        self.expired = 0                  # requests retired past deadline
        self._target_slots: int | None = None   # pending slot shrink
        self.hold_admissions = False      # fault/SLO gate: skip admission
        # shared across Scheduler instances of the same model: a server
        # creating one Scheduler per batch must not recompile the pick
        self._pick = model._jit_get("pick", self._build_pick)

    # ------------------------------------------------------------- interface
    def submit(self, req: Request, submit_time: float | None = None) -> None:
        """Queue a request.  Raises ValueError *here* — not by hanging the
        drain loop forever — when the request could never be admitted:
        its lifetime reservation must fit the pool even when every other
        request has retired."""
        S = int(req.inputs["tokens"].shape[1])
        if self.model.cfg.frontend == "vit":
            S += int(req.inputs["image_embeds"].shape[1])
        if req.max_new_tokens < 0:
            raise ValueError("max_new_tokens must be >= 0")
        if S + req.max_new_tokens > self.cache_len:
            raise ValueError(
                f"request uid={req.uid}: prompt ({S}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds cache_len={self.cache_len}")
        if self.paged:
            need = logical_blocks(S + req.max_new_tokens, self.block)
            cap = self.allocator.capacity      # pending-shrink aware
            if need > cap:
                raise ValueError(
                    f"request uid={req.uid} can never be admitted: prompt "
                    f"({S}) + max_new_tokens ({req.max_new_tokens}) needs "
                    f"{need} blocks of {self.block} tokens but the pool "
                    f"has only {cap}")
        t = self._now() if submit_time is None else submit_time
        self.queue.append(_Queued(
            req, S, t,
            deadline=None if req.deadline_s is None else t + req.deadline_s))

    def cancel(self, uid: int) -> bool:
        """Explicitly cancel a request, queued or in flight.  Retires it
        with ``finish_reason="cancelled"`` (partial tokens included) and
        frees its slot/blocks.  Returns False for an unknown uid."""
        for qi, q in enumerate(self.queue):
            if q.req.uid == uid:
                del self.queue[qi]
                self.finished.append(self._finish_queued(q, "cancelled"))
                self.cancelled += 1
                return True
        for i, s in enumerate(self.slots):
            if s is not None and s.uid == uid:
                self.finished.append(self._evict(i, "cancelled"))
                self.cancelled += 1
                return True
        return False

    def drop(self, uid: int) -> bool:
        """Remove a request — queued or in flight — WITHOUT recording a
        result: the slot/blocks are freed and nothing lands in
        ``finished``.  Journal replay uses this when a retire record is
        authoritative (the journaled tokens were already acknowledged to
        the client; the restored live copy must simply vanish).  Returns
        False for an unknown uid."""
        for qi, q in enumerate(self.queue):
            if q.req.uid == uid:
                del self.queue[qi]
                return True
        for i, s in enumerate(self.slots):
            if s is not None and s.uid == uid:
                if self.paged:
                    self._release_blocks(i)
                self.slots[i] = None
                return True
        return False

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def idle(self) -> bool:
        return not self.queue and self.num_active == 0

    def stats(self) -> dict:
        """Pool/paging counters for reporting (serve.py, bench_serve_tt)."""
        out = {"tokens_out": self.tokens_out, "steps_run": self.steps_run,
               "kv_pool_bytes": self.kv_pool_bytes(),
               "preemptions": self.preemptions,
               "cancelled": self.cancelled, "expired": self.expired}
        if self.chunk_prefill:
            out.update(chunk_size=self.chunk_size,
                       prefill_budget=self.prefill_budget,
                       chunk_lanes=self.chunk_lanes,
                       prefill_chunks=self.prefill_chunks)
        if self.paged:
            out.update(
                block_size=self.block, num_blocks=self.num_blocks,
                blocks_in_use=self.allocator.in_use,
                block_high_water=self.block_hwm,
                prefix_hit_tokens=self.prefix_hit_tokens,
                prefix_prompt_tokens=self.prefix_prompt_tokens,
                prefill_tokens_skipped=self.prefill_tokens_skipped,
                prefix_hit_rate=(
                    self.prefix_hit_tokens / self.prefix_prompt_tokens
                    if self.prefix_prompt_tokens else 0.0))
        return out

    def kv_pool_bytes(self) -> int:
        if self.cache is None:
            return 0
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.cache))

    def reset_stats(self) -> None:
        """Zero the reporting counters (after a warm-up request, so compile
        effects stay out of steady-state numbers).  Owned here so every
        counter added to :meth:`stats` gets excluded by construction."""
        self.finished.clear()
        self.tokens_out = self.steps_run = 0
        self.preemptions = self.cancelled = self.expired = 0
        self.prefill_chunks = 0
        if self.paged:
            self.block_hwm = self.allocator.in_use
            self.prefix_hit_tokens = self.prefix_prompt_tokens = 0
            self.prefill_tokens_skipped = 0

    def step(self) -> list[FinishedRequest]:
        """One scheduler tick: expire overdue work, land any drained
        resize, admit into free slots best-rank-first (paged mode
        additionally requires the block reservation to fit — admission by
        memory; preemption may evict lower-ranked slots), then run one
        masked decode step.  Returns the requests retired during this
        call."""
        done: list[FinishedRequest] = []
        self._expire(self._now(), done)
        self._apply_pending_resize()
        if not self.hold_admissions:
            self._admit_phase(done)
        if self.num_active:
            if self.chunk_prefill and any(
                    s is not None and s.prefill_pos is not None
                    for s in self.slots):
                self._mixed_once(done)
            else:
                self._decode_once(done)
        # retirements this step may have been the last thing a deferred
        # shrink was waiting on — land it now, not one step later
        self._apply_pending_resize()
        self.finished.extend(done)
        return done

    def run(self) -> dict[int, FinishedRequest]:
        """Drain queue + active slots; returns {uid: FinishedRequest}.

        Guards against silent hangs: a step that makes no progress at all
        (nothing admitted, decoded, retired or expired) while requests are
        still queued raises RuntimeError with the pool ledger instead of
        spinning forever."""
        out = {}
        while not self.idle:
            before = (len(self.queue), self.num_active, self.steps_run,
                      len(self.finished))
            for f in self.step():
                out[f.uid] = f
            after = (len(self.queue), self.num_active, self.steps_run,
                     len(self.finished))
            if before == after and after[1] == 0:
                q = self.queue[0]
                detail = ""
                if self.paged:
                    need = logical_blocks(
                        q.prompt_len + q.req.max_new_tokens, self.block)
                    detail = (f" (head uid={q.req.uid} needs {need} blocks, "
                              f"{self.allocator.available} available)")
                raise RuntimeError(
                    f"scheduler stalled: {len(self.queue)} queued requests, "
                    f"no active slots, and a step made no progress" + detail)
        return out

    # ----------------------------------------------------- deadlines/cancels
    def _finish_queued(self, q: _Queued, reason: str) -> FinishedRequest:
        """Retire a request straight out of the queue (cancel/deadline);
        a preempted re-queue keeps its partial tokens."""
        r = q.resume
        return FinishedRequest(
            uid=q.req.uid,
            tokens=np.asarray(r.tokens if r else [], np.int32),
            logprobs=np.asarray(r.logprobs if r else [], np.float32),
            finish_reason=reason, prompt_len=q.prompt_len,
            submit_time=q.submit_time, finish_time=self._now(),
            first_token_time=r.first_token_time if r else None)

    def _evict(self, i: int, reason: str) -> FinishedRequest:
        """Retire active slot ``i`` early (cancel/deadline): emit its
        partial tokens and free the slot + blocks."""
        f = self._retire(self.slots[i], reason)
        if self.paged:
            self._release_blocks(i)
        self.slots[i] = None
        return f

    def _expire(self, now: float, done: list[FinishedRequest]) -> None:
        """Retire everything past its deadline — queued requests before
        they ever reach a prefill, active slots with their partial tokens."""
        if any(q.deadline is not None and now >= q.deadline
               for q in self.queue):
            keep: deque[_Queued] = deque()
            for q in self.queue:
                if q.deadline is not None and now >= q.deadline:
                    done.append(self._finish_queued(q, "deadline"))
                    self.expired += 1
                else:
                    keep.append(q)
            self.queue = keep
        for i, s in enumerate(self.slots):
            if s is not None and s.deadline is not None \
                    and now >= s.deadline:
                done.append(self._evict(i, "deadline"))
                self.expired += 1

    # ------------------------------------------------------------ preemption
    @staticmethod
    def _rank(priority: int, submit_time: float) -> tuple:
        """Admission/preemption order: smaller sorts first (better).
        Higher priority wins; ties go to the older request."""
        return (-priority, submit_time)

    def _qrank(self, q: _Queued) -> tuple:
        return self._rank(q.req.priority, q.submit_time)

    def _srank(self, s: _Slot) -> tuple:
        return self._rank(s.priority, s.submit_time)

    def _slot_limit(self) -> int:
        """Admissible slot range: a pending shrink stops filling the
        draining tail."""
        return (self._target_slots if self._target_slots is not None
                else self.num_slots)

    def _admit_phase(self, done: list[FinishedRequest]) -> None:
        """Admit queued requests best-rank-first.  When the best queued
        request cannot start (no free slot, or its block reservation does
        not fit), preemption may evict a strictly lower-ranked active slot
        — rank order is static, so a preemptor can never itself be
        preempted by its victim and the top-ranked request in the system
        always runs to completion (anti-livelock).  If the best request
        still cannot start, admission stops: lower-ranked requests never
        jump over it."""
        while self.queue:
            qi = min(range(len(self.queue)),
                     key=lambda j: self._qrank(self.queue[j]))
            q = self.queue[qi]
            limit = self._slot_limit()
            free = next((i for i in range(limit) if self.slots[i] is None),
                        None)
            if free is None:
                if not self._preempt_for(q):
                    break
                continue                  # a slot was freed: retry
            if self._try_admit(q, free, done):
                del self.queue[qi]
                continue
            if not self._preempt_for(q):  # paged: blocks unavailable
                break

    def _preempt_for(self, q: _Queued) -> bool:
        """Evict the worst-ranked active slot if it ranks strictly below
        ``q``.  Returns True iff a victim was preempted."""
        if not self.preempt:
            return False
        cand = [(self._srank(s), i)
                for i, s in enumerate(self.slots) if s is not None]
        if not cand:
            return False
        rank, victim = max(cand)
        if rank <= self._qrank(q):        # never an equal-or-better victim
            return False
        self._preempt(victim)
        return True

    def _resume_tokens(self, s: _Slot) -> np.ndarray:
        """Token sequence of the resume prompt: original prompt followed
        by everything generated so far."""
        orig = np.asarray(s.req.inputs["tokens"]).reshape(-1)
        return np.concatenate([orig, np.asarray(s.tokens, orig.dtype)])

    def _preempt(self, i: int) -> None:
        """Evict slot ``i`` and requeue it for recompute.  Full blocks of
        already-computed KV are published to the prefix registry first, so
        re-admission reacquires them (refcount-0 evictable blocks survive
        unless the preemptor itself needs them) and recomputes only the
        tail.  The partial token/logprob/PRNG state rides along on the
        queue entry — the resumed stream is the same stream."""
        s = self.slots[i]
        if self.paged:
            blocks = self._slot_blocks[i]
            if self.prefix_cache and blocks:
                # KV rows exist for the prompt + all generated tokens except
                # last_tok (still pending as the next decode input); a
                # mid-prefill victim has valid KV only up to its chunk
                # cursor
                toks = self._resume_tokens(s)
                n_valid = (s.prefill_pos if s.prefill_pos is not None
                           else s.prompt_len + len(s.tokens) - 1)
                n_pub = min(n_valid // self.block, len(blocks))
                if n_pub > 0:
                    hashes = chain_hashes(toks[:n_pub * self.block],
                                          self.block)
                    for bid, h in zip(blocks[:n_pub], hashes):
                        self.allocator.publish(bid, h)
            self._release_blocks(i)
        self.slots[i] = None
        self.queue.append(_Queued(
            req=s.req, prompt_len=s.prompt_len, submit_time=s.submit_time,
            deadline=s.deadline,
            resume=_Resume(list(s.tokens), list(s.logprobs), s.key,
                           s.last_tok, s.first_token_time)))
        self.preemptions += 1

    # -------------------------------------------------------------- sampling
    def _build_pick(self):
        def pick(logits, keys, temps, topk):
            """logits [B,V]; keys [B,2] uint32 (ignored for greedy rows);
            temps [B] float32; topk [B] int32 (0 = no filter) →
            (tokens [B] int32, logprobs [B] float32).  One compiled pick
            serves every mix of per-request sampling params."""
            V = logits.shape[-1]
            lp = jax.nn.log_softmax(logits, -1)
            greedy = jnp.argmax(logits, -1)
            srt = jnp.sort(logits, axis=-1)[:, ::-1]          # descending
            kth = jnp.take_along_axis(
                srt, jnp.clip(topk - 1, 0, V - 1)[:, None], 1)[:, 0]
            keep = (topk[:, None] <= 0) | (logits >= kth[:, None])
            safe_t = jnp.where(temps > 0, temps, 1.0)[:, None]
            scaled = jnp.where(keep, logits, NEG_INF) / safe_t
            sampled = jax.vmap(jax.random.categorical)(keys, scaled)
            tok = jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
            return tok, jnp.take_along_axis(lp, tok[:, None], -1)[:, 0]

        return jax.jit(pick)

    def _gather_logits(self, logits: jax.Array) -> jax.Array:
        """Collapse tensor-parallel logits to replicated before the pick.

        With a sharded LM head the decode step emits logits partitioned on
        the vocab axis; feeding them to ``_pick`` as-is would compile the
        top-k sort into a distributed sort (~40 collectives per step on a
        2-device mesh, measured — the rendezvous cost dwarfs the math at
        decode shapes).  One explicit all-gather of [B, V] instead keeps
        the pick executable collective-free and mesh-agnostic."""
        if self.mesh is None:
            return logits
        return jax.device_put(
            logits, jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec()))

    def _req_key(self, req: Request) -> jax.Array | None:
        if req.temperature <= 0.0:
            return None                   # greedy: no randomness consumed
        if req.key is not None:
            return req.key
        base = (self.base_key if self.base_key is not None
                else jax.random.PRNGKey(0))
        # uids may be negative (warm-up requests); fold_in wants uint32
        return jax.random.fold_in(base, req.uid & 0xFFFFFFFF)

    def _next_key(self, slot: _Slot) -> jax.Array:
        slot.key, sub = jax.random.split(slot.key)
        return sub

    def _pick_one(self, logits_row, slot: _Slot) -> tuple[int, float]:
        """Pick for a single request (admission path): same jitted pick as
        the batched decode, batch dim 1."""
        if slot.temperature > 0.0:
            keys = self._next_key(slot)[None]
        else:
            keys = jnp.zeros((1, 2), jnp.uint32)
        tok, lp = self._pick(
            self._gather_logits(logits_row[None]), keys,
            jnp.asarray([slot.temperature], jnp.float32),
            jnp.asarray([slot.top_k], jnp.int32))
        return int(tok[0]), float(lp[0])

    # ------------------------------------------------------------ pool build
    def _ensure_pool(self, row_cache: dict) -> None:
        """Allocate the pool from the first prefilled row's cache tree
        (guarantees dtype/shape agreement with what prefill produces)."""
        if self.cache is not None:
            return
        B = self.num_slots
        if not self.paged:
            def expand(leaf):
                return jnp.zeros(leaf.shape[:1] + (B,) + leaf.shape[2:],
                                 leaf.dtype)

            self.cache = {"pos": jnp.zeros((B,), jnp.int32)}
            for k, v in row_cache.items():
                if k != "pos":
                    self.cache[k] = jax.tree.map(expand, v)
            return
        nb1 = self.num_blocks + 1         # + write-sentinel block
        cache: dict = {
            "pos": jnp.zeros((B,), jnp.int32),
            "block_tables": jnp.full((B, self.max_blocks), self.num_blocks,
                                     jnp.int32)}
        for gi, (period, _count) in enumerate(self.model.groups):
            g = {}
            for i, bd in enumerate(period):
                kinds = block_cache_kinds(bd)
                b = {}
                for name, row in row_cache[f"g{gi}"][f"b{i}"].items():
                    if kinds[name] == "slot":
                        b[name] = jnp.zeros(
                            row.shape[:1] + (B,) + row.shape[2:], row.dtype)
                    else:                 # row [layers, 1, T, ...] → arena
                        b[name] = jnp.zeros(
                            (row.shape[0], nb1, self.block) + row.shape[3:],
                            row.dtype)
                g[f"b{i}"] = b
            cache[f"g{gi}"] = g
        self.cache = cache
        self._constrain_pool()

    def _constrain_pool(self) -> None:
        """Re-assert the pool's device placement (no-op without a mesh, or
        for leaves already laid out correctly).  Called wherever the pool
        is (re)built from host data or eager reshapes — pool build, resize
        remaps, snapshot restore — so the decode executable always sees
        the same input sharding and never recompiles mid-stream."""
        if self.mesh is not None and self.cache is not None:
            self.cache = jax.device_put(
                self.cache,
                shd.serve_cache_shardings(
                    self.cache, self.mesh,
                    batch=None if self.paged else self.num_slots))

    # -------------------------------------------------------------- admission
    def _try_admit(self, q: _Queued, slot_idx: int,
                   done: list[FinishedRequest]) -> bool:
        """Admit the queue head into ``slot_idx``.  Returns False when the
        paged pool cannot reserve the request's blocks yet (the request
        stays queued; retirements will free blocks)."""
        req = q.req
        if req.max_new_tokens == 0:       # nothing to generate: no prefill
            done.append(FinishedRequest(
                uid=req.uid, tokens=np.zeros((0,), np.int32),
                logprobs=np.zeros((0,), np.float32), finish_reason="length",
                prompt_len=q.prompt_len, submit_time=q.submit_time,
                finish_time=self._now()))
            return True
        if self.chunk_prefill:
            return self._admit_chunked(q, slot_idx)
        if self.paged:
            return self._admit_paged(q, slot_idx, done)
        self._admit_dense(q, slot_idx, done)
        return True

    def _admit_inputs(self, q: _Queued) -> tuple[dict, int]:
        """Model inputs + effective prompt length for an admission.  A
        preempted re-queue resumes with prompt = original prompt + tokens
        generated so far: the prefill (or resume prefill on a prefix hit)
        rebuilds the KV state and its last-position logits pick the next
        token — exactly the pick the interrupted decode step would have
        made."""
        if q.resume is None:
            return q.req.inputs, q.prompt_len
        orig = np.asarray(q.req.inputs["tokens"])
        toks = np.concatenate(
            [orig, np.asarray([q.resume.tokens], orig.dtype)], axis=1)
        inputs = dict(q.req.inputs, tokens=jnp.asarray(toks))
        return inputs, q.prompt_len + len(q.resume.tokens)

    def _row_prefill(self, inputs):
        if self.bucket_prompts:
            fn = self.model.jitted_prefill_bucketed(self.cache_len)
            return fn(self.params, inputs)
        return self.model.jitted_prefill(
            self.cache_len,
            shape_key=int(inputs["tokens"].shape[1]))(self.params, inputs)

    def _start_slot(self, q: _Queued) -> _Slot:
        req = q.req
        s = _Slot(uid=req.uid, req=req, max_new=req.max_new_tokens,
                  key=self._req_key(req), prompt_len=q.prompt_len,
                  submit_time=q.submit_time,
                  temperature=float(req.temperature),
                  top_k=int(req.top_k), priority=int(req.priority),
                  deadline=q.deadline)
        if q.resume is not None:          # continue the interrupted stream
            s.tokens = list(q.resume.tokens)
            s.logprobs = list(q.resume.logprobs)
            s.key = q.resume.key          # PRNG state, not a fresh fold_in
            s.last_tok = q.resume.last_tok
            s.first_token_time = q.resume.first_token_time
        return s

    def _emit(self, slot: _Slot, tok: int, lp: float) -> None:
        """Append one generated token to a slot: TTFT stamp on the first,
        streaming callback on every one.  The single funnel for token
        emission — admission first-tokens, chunk-completion first-tokens
        and decode steps all come through here."""
        slot.tokens.append(tok)
        slot.logprobs.append(lp)
        slot.last_tok = tok
        self.tokens_out += 1
        if slot.first_token_time is None:
            slot.first_token_time = self._now()
        cb = slot.req.on_token
        if cb is not None:
            cb(slot.uid, len(slot.tokens) - 1, tok, lp)

    def _admit_dense(self, q: _Queued, slot_idx: int,
                     done: list[FinishedRequest]) -> None:
        inputs, _ = self._admit_inputs(q)
        logits, row_cache = self._row_prefill(inputs)
        slot = self._start_slot(q)
        tok, lp = self._pick_one(logits[0, -1], slot)
        self._emit(slot, tok, lp)
        if self._finished_reason(slot):
            done.append(self._retire(slot))
            return                        # never occupied a decode slot
        self._ensure_pool(row_cache)
        self.cache = self.model.jitted_splice()(
            self.cache, row_cache, jnp.asarray(slot_idx, jnp.int32))
        self.slots[slot_idx] = slot

    def _admit_paged(self, q: _Queued, slot_idx: int,
                     done: list[FinishedRequest]) -> bool:
        req = q.req
        inputs, S = self._admit_inputs(q)
        blk = self.block
        alloc = self.allocator
        # lifetime reservation — invariant under preemption/resume:
        # original prompt + already-generated + remaining budget
        need = logical_blocks(min(q.prompt_len + req.max_new_tokens,
                                  self.cache_len), blk)
        # ---- prefix lookup: acquire the longest chain of resident blocks
        hashes: list[bytes] = []
        shared: list[int] = []
        if self.prefix_cache:
            hashes = chain_hashes(np.asarray(inputs["tokens"]), blk)
            for h in hashes:
                bid = alloc.acquire(h)
                if bid is None:
                    break
                shared.append(bid)
        matched = len(shared)
        covered = matched * blk
        full_cover = matched > 0 and covered >= S
        # resume must compute >= 1 token for logits: full coverage COWs the
        # last matched block and recomputes only its final token
        start = S - 1 if full_cover else covered
        fresh_needed = need - matched + (1 if full_cover else 0)
        # if we are the COW source's only owner, the COW's decref returns
        # it to the pool mid-admission — credit it, or an idle pool could
        # refuse a request that actually fits (admission livelock)
        credit = (1 if full_cover and alloc.refcount(shared[-1]) == 1
                  else 0)
        if fresh_needed > alloc.available + credit:
            for bid in shared:            # rollback: request stays queued
                alloc.decref(bid)
            return False
        # ---- build source/destination tables (dst != src ⇒ COW block)
        src = list(shared)
        dst = list(shared)
        if full_cover:
            dst[-1] = alloc.cow(shared[-1])
        fresh = [alloc.alloc() for _ in range(need - len(dst))]
        src += fresh
        dst += fresh
        sentinel = self.num_blocks
        src_t = np.full(self.max_blocks, sentinel, np.int32)
        dst_t = np.full(self.max_blocks, sentinel, np.int32)
        src_t[:len(src)] = src
        dst_t[:len(dst)] = dst
        # ---- prefill: full prompt (splice) or suffix only (resume)
        slot = self._start_slot(q)
        if start == 0:
            logits, row_cache = self._row_prefill(inputs)
            self._ensure_pool(row_cache)
            self.cache = self.model.jitted_splice_paged()(
                self.cache, row_cache, jnp.asarray(slot_idx, jnp.int32),
                jnp.asarray(dst_t))
        else:
            suffix = {k: (v[:, start:] if k == "tokens" else v)
                      for k, v in inputs.items()}
            logits, self.cache = self.model.jitted_prefill_resume(
                self.cache_len)(self.params, suffix, self.cache, slot_idx,
                                src_t, dst_t, start, S - start)
            self.prefill_tokens_skipped += start
        # ---- publish full prompt blocks for future sharing
        if self.prefix_cache:
            for i in range(min(len(hashes), len(dst))):
                alloc.publish(dst[i], hashes[i])
        self._slot_blocks[slot_idx] = dst
        self.prefix_prompt_tokens += S
        self.prefix_hit_tokens += min(covered, S)
        self.block_hwm = max(self.block_hwm, alloc.in_use)
        # ---- first token
        tok, lp = self._pick_one(logits[0, -1], slot)
        self._emit(slot, tok, lp)
        if self._finished_reason(slot):
            done.append(self._retire(slot))
            self._release_blocks(slot_idx)
            return True                   # never occupied a decode slot
        self.slots[slot_idx] = slot
        return True

    def _ensure_pool_chunked(self) -> None:
        """Chunked admission performs no monolithic prefill, so the pool
        cannot be built "from the first prefilled row"; bootstrap it from
        a zeroed single-row cache with the same shapes and dtypes."""
        if self.cache is None:
            self._ensure_pool(self.model.init_cache(
                1, self.cache_len, dtype=self.model.param_dtype))

    def _admit_chunked(self, q: _Queued, slot_idx: int) -> bool:
        """Admit under chunked prefill: reserve memory and arm the chunk
        state machine — NO prefill compute happens at admission.  The
        mixed step streams the prompt through chunk lanes and the first
        token is picked at chunk completion.  Paged reservation/prefix
        logic mirrors :meth:`_admit_paged` exactly (same lifetime need,
        same COW-credit trick), so admission-by-memory and preemption
        behave identically in both modes.  Returns False when the block
        reservation cannot fit yet."""
        inputs, S = self._admit_inputs(q)
        toks_np = np.asarray(inputs["tokens"]).reshape(-1).astype(np.int32)
        self._ensure_pool_chunked()
        if not self.paged:
            slot = self._start_slot(q)
            slot.prefill_pos = 0
            slot.prefill_toks = toks_np
            self.slots[slot_idx] = slot
            return True
        blk = self.block
        alloc = self.allocator
        need = logical_blocks(min(q.prompt_len + q.req.max_new_tokens,
                                  self.cache_len), blk)
        shared: list[int] = []
        if self.prefix_cache:
            for h in chain_hashes(np.asarray(inputs["tokens"]), blk):
                bid = alloc.acquire(h)
                if bid is None:
                    break
                shared.append(bid)
        matched = len(shared)
        covered = matched * blk
        full_cover = matched > 0 and covered >= S
        # full coverage still computes >= 1 chunk token for logits
        start = S - 1 if full_cover else covered
        fresh_needed = need - matched + (1 if full_cover else 0)
        credit = (1 if full_cover and alloc.refcount(shared[-1]) == 1
                  else 0)
        if fresh_needed > alloc.available + credit:
            for bid in shared:            # rollback: request stays queued
                alloc.decref(bid)
            return False
        dst = list(shared)
        if full_cover:
            dst[-1] = alloc.cow(shared[-1])
            if dst[-1] != shared[-1]:
                # chunk passes read AND write through the slot's own
                # table: materialize the to-be-partially-overwritten tail
                # block eagerly (the monolithic resume path instead keeps
                # src/dst tables apart inside one prefill call)
                self.cache = self.model.jitted_copy_blocks()(
                    self.cache, jnp.asarray(shared[-1], jnp.int32),
                    jnp.asarray(dst[-1], jnp.int32))
        dst += [alloc.alloc() for _ in range(need - len(dst))]
        dst_t = np.full(self.max_blocks, self.num_blocks, np.int32)
        dst_t[:len(dst)] = dst
        self._slot_blocks[slot_idx] = dst
        self.prefix_prompt_tokens += S
        self.prefix_hit_tokens += min(covered, S)
        self.prefill_tokens_skipped += start
        self.block_hwm = max(self.block_hwm, alloc.in_use)
        slot = self._start_slot(q)
        slot.prefill_pos = int(start)
        slot.prefill_toks = toks_np
        slot.prefill_table = dst_t
        self.slots[slot_idx] = slot
        return True

    def _release_blocks(self, slot_idx: int) -> None:
        blocks = self._slot_blocks[slot_idx]
        if blocks is not None:
            for bid in blocks:
                self.allocator.decref(bid)
            self._slot_blocks[slot_idx] = None

    # ----------------------------------------------------------------- resize
    def resize(self, num_slots: int | None = None,
               num_blocks: int | None = None) -> dict:
        """Live pool resize — the knob an autoscaler turns (ROADMAP 4).
        Growth applies immediately (slot rows / arena blocks are padded
        in place, new block ids join the free list).  A shrink never
        drops in-flight requests: the slot tail stops admitting and the
        block fence stops re-issuing high ids, and the actual array
        slicing lands at a later ``step()`` once the tail has drained.
        Returns the current/pending geometry."""
        if num_slots is not None:
            if num_slots < 1:
                raise ValueError("num_slots must be >= 1")
            if num_slots >= self.num_slots:
                if num_slots > self.num_slots:
                    self._grow_slots(num_slots)
                self._target_slots = None
            else:
                self._target_slots = num_slots
                self._apply_slot_shrink()
        if num_blocks is not None:
            if not self.paged:
                raise ValueError("num_blocks resize requires paged=True")
            old = self.num_blocks
            if self.allocator.resize(num_blocks):
                if num_blocks != old:
                    self._remap_arenas(old, num_blocks)
                    self.num_blocks = num_blocks
            # else: fenced — _apply_pending_resize lands it when drained
        return {"num_slots": self.num_slots,
                "num_blocks": self.num_blocks if self.paged else None,
                "pending_slots": self._target_slots,
                "pending_blocks": (self.allocator.pending_target
                                   if self.paged else None)}

    def _apply_pending_resize(self) -> None:
        self._apply_slot_shrink()
        if self.paged and self.allocator.shrink_ready:
            old, new = self.num_blocks, self.allocator.pending_target
            self.allocator.finalize_shrink()
            self._remap_arenas(old, new)
            self.num_blocks = new

    def _grow_slots(self, n: int) -> None:
        old = self.num_slots
        self.slots.extend([None] * (n - old))
        if self.paged:
            self._slot_blocks.extend([None] * (n - old))
        if self.cache is not None:
            self.cache = self._reshape_slots(self.cache, n)
        self.num_slots = n
        self._constrain_pool()

    def _apply_slot_shrink(self) -> bool:
        """Land a pending slot shrink once the tail slots have drained."""
        t = self._target_slots
        if t is None:
            return True
        if any(self.slots[i] is not None
               for i in range(t, self.num_slots)):
            return False                  # defer: tail still busy
        self.slots = self.slots[:t]
        if self.paged:
            self._slot_blocks = self._slot_blocks[:t]
        if self.cache is not None:
            self.cache = self._reshape_slots(self.cache, t)
        self.num_slots = t
        self._constrain_pool()
        self._target_slots = None
        return True

    @staticmethod
    def _axis_resize(leaf, n: int, axis: int):
        cur = leaf.shape[axis]
        if n == cur:
            return leaf
        if n < cur:
            return jax.lax.slice_in_dim(leaf, 0, n, axis=axis)
        pad = jnp.zeros(leaf.shape[:axis] + (n - cur,) + leaf.shape[axis + 1:],
                        leaf.dtype)
        return jnp.concatenate([leaf, pad], axis=axis)

    def _reshape_slots(self, cache: dict, n: int) -> dict:
        """Pad (grow) or slice (drained shrink) every slot-dimensioned
        leaf to ``n`` slots; arenas are slot-independent and untouched."""
        out = {"pos": self._axis_resize(cache["pos"], n, 0)}
        if not self.paged:
            for k, v in cache.items():
                if k != "pos":
                    out[k] = jax.tree.map(
                        lambda leaf: self._axis_resize(leaf, n, 1), v)
            return out
        bt = cache["block_tables"]
        if n < bt.shape[0]:
            out["block_tables"] = bt[:n]
        else:                             # fresh rows point at the sentinel
            pad = jnp.full((n - bt.shape[0], bt.shape[1]),
                           self.num_blocks, bt.dtype)
            out["block_tables"] = jnp.concatenate([bt, pad], axis=0)
        for gi, (period, _count) in enumerate(self.model.groups):
            g = {}
            for i, bd in enumerate(period):
                kinds = block_cache_kinds(bd)
                g[f"b{i}"] = {
                    name: (self._axis_resize(leaf, n, 1)
                           if kinds[name] == "slot" else leaf)
                    for name, leaf in cache[f"g{gi}"][f"b{i}"].items()}
            out[f"g{gi}"] = g
        return out

    def _remap_arenas(self, old_nb: int, new_nb: int) -> None:
        """Reshape every arena leaf ``[layers, old_nb+1, block, …]`` to the
        new block count and move the write sentinel to its new index.  Any
        table entry at or above ``min(old, new)`` is a sentinel reference
        or a stale retired-slot id — both collapse onto the new sentinel
        (live ids are below the fence by construction)."""
        if self.cache is None:
            return
        cache = dict(self.cache)
        bt = cache["block_tables"]
        cache["block_tables"] = jnp.where(
            bt >= min(old_nb, new_nb), jnp.asarray(new_nb, bt.dtype), bt)
        for gi, (period, _count) in enumerate(self.model.groups):
            g = {}
            for i, bd in enumerate(period):
                kinds = block_cache_kinds(bd)
                b = {}
                for name, leaf in cache[f"g{gi}"][f"b{i}"].items():
                    if kinds[name] == "slot":
                        b[name] = leaf
                    elif new_nb > old_nb:
                        # grow: the old sentinel slab becomes data block
                        # ``old_nb`` (free-listed, content meaningless)
                        b[name] = self._axis_resize(leaf, new_nb + 1, 1)
                    else:
                        # shrink: drained tail sliced off; zero the slab
                        # that becomes the new sentinel
                        b[name] = leaf[:, :new_nb + 1].at[:, new_nb].set(0)
                g[f"b{i}"] = b
            cache[f"g{gi}"] = g
        self.cache = cache
        self._constrain_pool()

    # --------------------------------------------------------------- snapshot
    SNAPSHOT_VERSION = 1

    def snapshot(self) -> dict:
        """Host-side snapshot of the complete serving state: queue, slots
        (partial tokens + per-request PRNG stream state), allocator
        ledger, pool cache contents and counters.  Everything is numpy /
        plain python — ``serving.faults.save_snapshot`` persists it, and
        :meth:`from_snapshot` resumes mid-stream with surviving token
        streams bit-identical to an uninterrupted run (the serving twin
        of ``training/fault.py``'s checkpoint/restart contract)."""
        def arr(x):
            return None if x is None else np.asarray(x)

        def enc_req(req: Request) -> dict:
            return {"uid": req.uid,
                    "inputs": {k: np.asarray(v)
                               for k, v in req.inputs.items()},
                    "max_new_tokens": req.max_new_tokens,
                    "key": arr(req.key), "temperature": req.temperature,
                    "top_k": req.top_k, "priority": req.priority,
                    "deadline_s": req.deadline_s}

        def enc_resume(r: _Resume | None):
            return None if r is None else {
                "tokens": list(r.tokens), "logprobs": list(r.logprobs),
                "key": arr(r.key), "last_tok": r.last_tok,
                "first_token_time": r.first_token_time}

        snap = {
            "version": self.SNAPSHOT_VERSION,
            "now": self._now(),
            "config": {
                "num_slots": self.num_slots, "cache_len": self.cache_len,
                "eos_id": self.eos_id, "paged": self.paged,
                "block_size": self.block if self.paged else None,
                "num_blocks": self.num_blocks if self.paged else None,
                "prefix_cache": (self.prefix_cache if self.paged else True),
                "bucket_prompts": self.bucket_prompts,
                "preempt": self.preempt,
                "chunk_prefill": self.chunk_prefill,
                "chunk_size": self.chunk_size,
                "prefill_budget": self.prefill_budget},
            "base_key": arr(self.base_key),
            "queue": [{"req": enc_req(q.req), "prompt_len": q.prompt_len,
                       "submit_time": q.submit_time, "deadline": q.deadline,
                       "resume": enc_resume(q.resume)} for q in self.queue],
            "slots": [None if s is None else
                      {"req": enc_req(s.req), "prompt_len": s.prompt_len,
                       "submit_time": s.submit_time, "deadline": s.deadline,
                       "temperature": s.temperature, "top_k": s.top_k,
                       "priority": s.priority, "tokens": list(s.tokens),
                       "logprobs": list(s.logprobs), "last_tok": s.last_tok,
                       "key": arr(s.key),
                       "first_token_time": s.first_token_time,
                       "prefill_pos": s.prefill_pos} for s in self.slots],
            "finished": [{"uid": f.uid, "tokens": np.asarray(f.tokens),
                          "logprobs": np.asarray(f.logprobs),
                          "finish_reason": f.finish_reason,
                          "prompt_len": f.prompt_len,
                          "submit_time": f.submit_time,
                          "finish_time": f.finish_time,
                          "first_token_time": f.first_token_time}
                         for f in self.finished],
            "target_slots": self._target_slots,
            "counters": {"steps_run": self.steps_run,
                         "tokens_out": self.tokens_out,
                         "preemptions": self.preemptions,
                         "cancelled": self.cancelled,
                         "expired": self.expired,
                         "prefill_chunks": self.prefill_chunks},
            "cache": (None if self.cache is None
                      else jax.tree.map(np.asarray, self.cache)),
        }
        if self.paged:
            snap["slot_blocks"] = [None if b is None else list(b)
                                   for b in self._slot_blocks]
            snap["allocator"] = self.allocator.state()
            snap["counters"].update(
                block_hwm=self.block_hwm,
                prefix_hit_tokens=self.prefix_hit_tokens,
                prefix_prompt_tokens=self.prefix_prompt_tokens,
                prefill_tokens_skipped=self.prefill_tokens_skipped)
        return snap

    @classmethod
    def from_snapshot(cls, model: Model, params, snap: dict, *,
                      clock=None, rebase_clock: bool = False,
                      mesh=None) -> "Scheduler":
        """Rebuild a scheduler mid-stream from :meth:`snapshot`.  Pass
        ``rebase_clock=True`` when restoring in a *new process* (the
        monotonic clock rebased): pending submit times and deadlines are
        shifted so in-flight TTLs keep their remaining budget.

        Snapshots are mesh-agnostic (host-side numpy, gathered at capture
        time): pass ``mesh`` to restore onto any device topology — the
        pool is re-partitioned per DESIGN.md §14 on load, so a snapshot
        taken on one device restores onto four and vice versa."""
        if int(snap.get("version", -1)) != cls.SNAPSHOT_VERSION:
            raise ValueError(
                f"snapshot version {snap.get('version')!r} != "
                f"{cls.SNAPSHOT_VERSION}")
        cfg = snap["config"]
        base_key = snap.get("base_key")
        sched = cls(
            model, params, num_slots=int(cfg["num_slots"]),
            cache_len=int(cfg["cache_len"]),
            eos_id=None if cfg["eos_id"] is None else int(cfg["eos_id"]),
            key=None if base_key is None else jnp.asarray(base_key),
            paged=bool(cfg["paged"]),
            block_size=int(cfg["block_size"] or 64),
            num_blocks=(None if cfg["num_blocks"] is None
                        else int(cfg["num_blocks"])),
            prefix_cache=bool(cfg["prefix_cache"]),
            bucket_prompts=bool(cfg["bucket_prompts"]),
            preempt=bool(cfg["preempt"]), clock=clock, mesh=mesh,
            chunk_prefill=bool(cfg.get("chunk_prefill", False)),
            chunk_size=int(cfg.get("chunk_size") or 64),
            prefill_budget=(None if cfg.get("prefill_budget") is None
                            else int(cfg["prefill_budget"])))
        shift = (sched._now() - float(snap["now"])) if rebase_clock else 0.0

        def t_of(v):
            return None if v is None else float(v) + shift

        def dec_key(k):
            return None if k is None else jnp.asarray(k)

        def dec_req(d: dict) -> Request:
            return Request(
                uid=int(d["uid"]),
                inputs={k: jnp.asarray(v) for k, v in d["inputs"].items()},
                max_new_tokens=int(d["max_new_tokens"]),
                key=dec_key(d["key"]), temperature=float(d["temperature"]),
                top_k=int(d["top_k"]), priority=int(d["priority"]),
                deadline_s=(None if d["deadline_s"] is None
                            else float(d["deadline_s"])))

        def dec_resume(d):
            return None if d is None else _Resume(
                tokens=[int(t) for t in d["tokens"]],
                logprobs=[float(x) for x in d["logprobs"]],
                key=dec_key(d["key"]), last_tok=int(d["last_tok"]),
                first_token_time=t_of(d.get("first_token_time")))

        sched.queue = deque(
            _Queued(req=dec_req(d["req"]), prompt_len=int(d["prompt_len"]),
                    submit_time=float(d["submit_time"]) + shift,
                    deadline=t_of(d["deadline"]),
                    resume=dec_resume(d["resume"]))
            for d in snap["queue"])
        slots: list[_Slot | None] = []
        for d in snap["slots"]:
            if d is None:
                slots.append(None)
                continue
            req = dec_req(d["req"])
            slots.append(_Slot(
                uid=req.uid, req=req, max_new=req.max_new_tokens,
                key=dec_key(d["key"]), prompt_len=int(d["prompt_len"]),
                submit_time=float(d["submit_time"]) + shift,
                temperature=float(d["temperature"]), top_k=int(d["top_k"]),
                priority=int(d["priority"]), deadline=t_of(d["deadline"]),
                tokens=[int(t) for t in d["tokens"]],
                logprobs=[float(x) for x in d["logprobs"]],
                last_tok=int(d["last_tok"]),
                first_token_time=t_of(d.get("first_token_time")),
                prefill_pos=(None if d.get("prefill_pos") is None
                             else int(d["prefill_pos"]))))
        sched.slots = slots
        # mid-prefill slots rebuild their host-side chunk inputs (the
        # effective prompt is derivable: original prompt + resume tokens)
        for s in sched.slots:
            if s is not None and s.prefill_pos is not None:
                s.prefill_toks = sched._resume_tokens(s).astype(np.int32)
        sched.finished = [FinishedRequest(
            uid=int(f["uid"]), tokens=np.asarray(f["tokens"], np.int32),
            logprobs=np.asarray(f["logprobs"], np.float32),
            finish_reason=str(f["finish_reason"]),
            prompt_len=int(f["prompt_len"]),
            submit_time=float(f["submit_time"]),
            finish_time=float(f["finish_time"]),
            first_token_time=(None if f.get("first_token_time") is None
                              else float(f["first_token_time"])))
            for f in snap["finished"]]
        c = snap["counters"]
        sched.steps_run = int(c["steps_run"])
        sched.tokens_out = int(c["tokens_out"])
        sched.preemptions = int(c["preemptions"])
        sched.cancelled = int(c["cancelled"])
        sched.expired = int(c["expired"])
        sched.prefill_chunks = int(c.get("prefill_chunks", 0))
        sched._target_slots = (None if snap["target_slots"] is None
                               else int(snap["target_slots"]))
        if snap["cache"] is not None:
            sched.cache = jax.tree.map(jnp.asarray, snap["cache"])
            sched._constrain_pool()
        if sched.paged:
            sched.allocator = BlockAllocator.from_state(snap["allocator"])
            sched._slot_blocks = [
                None if b is None else [int(x) for x in b]
                for b in snap["slot_blocks"]]
            sched.block_hwm = int(c["block_hwm"])
            sched.prefix_hit_tokens = int(c["prefix_hit_tokens"])
            sched.prefix_prompt_tokens = int(c["prefix_prompt_tokens"])
            sched.prefill_tokens_skipped = int(c["prefill_tokens_skipped"])
            for i, s in enumerate(sched.slots):
                if s is not None and s.prefill_pos is not None \
                        and sched._slot_blocks[i] is not None:
                    t = np.full(sched.max_blocks, sched.num_blocks,
                                np.int32)
                    blocks = sched._slot_blocks[i]
                    t[:len(blocks)] = blocks
                    s.prefill_table = t
        return sched

    # ---------------------------------------------------------------- decode
    def _decode_arrays(self):
        """Host-side inputs of the masked decode pass.  Mid-prefill slots
        are NOT decode-active: the decode pass's per-slot writes are
        masked off for them, leaving their partially-built rows alone."""
        B = self.num_slots
        toks = np.zeros((B, 1), np.int32)
        active = np.zeros((B,), bool)
        temps = np.zeros((B,), np.float32)
        topk = np.zeros((B,), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None and s.prefill_pos is None:
                toks[i, 0] = s.last_tok
                active[i] = True
                temps[i] = s.temperature
                topk[i] = s.top_k
        return toks, active, temps, topk

    def _finish_decode(self, logits, temps, topk,
                       done: list[FinishedRequest]) -> None:
        """Pick + emit + retire for one decode pass's logits.  Slots still
        prefilling neither consume PRNG splits nor receive tokens."""
        decoding = [s if s is not None and s.prefill_pos is None else None
                    for s in self.slots]
        if any(s is not None and s.temperature > 0.0 for s in decoding):
            keys = jnp.stack([
                self._next_key(s) if s is not None and s.temperature > 0.0
                else jnp.zeros((2,), jnp.uint32)
                for s in decoding])
        else:                             # all greedy: no splits consumed
            keys = jnp.zeros((self.num_slots, 2), jnp.uint32)
        tok, lp = self._pick(self._gather_logits(logits[:, 0, :]), keys,
                             jnp.asarray(temps), jnp.asarray(topk))
        tok, lp = np.asarray(tok), np.asarray(lp)
        self.steps_run += 1
        for i, s in enumerate(decoding):
            if s is None:
                continue
            self._emit(s, int(tok[i]), float(lp[i]))
            if self._finished_reason(s):
                done.append(self._retire(s))
                if self.paged:
                    self._release_blocks(i)
                self.slots[i] = None

    def _decode_once(self, done: list[FinishedRequest]) -> None:
        toks, active, temps, topk = self._decode_arrays()
        logits, self.cache = self.model.jitted_decode_step_masked(self.mesh)(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(active))
        self._finish_decode(logits, temps, topk, done)

    def _mixed_once(self, done: list[FinishedRequest]) -> None:
        """One fused serving step: up to ``chunk_lanes`` prefill chunks
        (best-rank-first among mid-prefill slots) run alongside the
        masked decode of every fully-prefilled slot — one traced program
        per (K, C) shape, so the zero-replan contract holds under
        chunked prefill."""
        K, C = self.chunk_lanes, self.chunk_size
        toks, active, temps, topk = self._decode_arrays()
        pref = sorted(
            (self._srank(s), i) for i, s in enumerate(self.slots)
            if s is not None and s.prefill_pos is not None)
        lanes: list[tuple[int, int, int]] = []
        ck_tok = np.zeros((K, C), np.int32)
        ck_slot = np.zeros((K,), np.int32)
        ck_start = np.zeros((K,), np.int32)
        ck_true = np.ones((K,), np.int32)   # 1 keeps unused lanes in-range
        ck_active = np.zeros((K,), bool)
        ck_tables = (np.full((K, self.max_blocks), self.num_blocks,
                             np.int32) if self.paged else None)
        for j, (_, i) in enumerate(pref[:K]):
            s = self.slots[i]
            start = s.prefill_pos
            take = min(C, len(s.prefill_toks) - start)
            ck_tok[j, :take] = s.prefill_toks[start:start + take]
            ck_slot[j] = i
            ck_start[j] = start
            ck_true[j] = take
            ck_active[j] = True
            if self.paged:
                ck_tables[j] = s.prefill_table
            lanes.append((i, start, take))
        args = [self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(active), jnp.asarray(ck_tok),
                jnp.asarray(ck_slot), jnp.asarray(ck_start),
                jnp.asarray(ck_true), jnp.asarray(ck_active)]
        if self.paged:
            args.append(jnp.asarray(ck_tables))
        logits, ck_logits, self.cache = self.model.jitted_mixed_step(
            K, C, self.mesh)(*args)
        self.prefill_chunks += len(lanes)
        if active.any():
            self._finish_decode(logits, temps, topk, done)
        for j, (i, start, take) in enumerate(lanes):
            s = self.slots[i]
            s.prefill_pos = start + take
            if s.prefill_pos >= len(s.prefill_toks):
                self._complete_prefill(i, s, ck_logits[j], done)

    def _complete_prefill(self, i: int, s: _Slot, logits_row,
                          done: list[FinishedRequest]) -> None:
        """A lane just processed its final chunk: publish the prompt's
        full blocks for prefix sharing, pick the first generated token
        from the lane logits (same per-request PRNG discipline as a
        monolithic admission pick) and flip the slot to decode mode."""
        if self.paged and self.prefix_cache:
            blocks = self._slot_blocks[i] or []
            hashes = chain_hashes(s.prefill_toks, self.block)
            for bid, h in zip(blocks, hashes):
                self.allocator.publish(bid, h)
        tok, lp = self._pick_one(logits_row, s)
        s.prefill_pos = None
        s.prefill_toks = None
        s.prefill_table = None
        self._emit(s, tok, lp)
        if self._finished_reason(s):
            done.append(self._retire(s))
            if self.paged:
                self._release_blocks(i)
            self.slots[i] = None

    def _finished_reason(self, slot: _Slot) -> str | None:
        if self.eos_id is not None and slot.last_tok == self.eos_id:
            return "eos"
        if len(slot.tokens) >= slot.max_new:
            return "length"
        return None

    def _retire(self, slot: _Slot,
                reason: str | None = None) -> FinishedRequest:
        return FinishedRequest(
            uid=slot.uid,
            tokens=np.asarray(slot.tokens, np.int32),
            logprobs=np.asarray(slot.logprobs, np.float32),
            finish_reason=reason or self._finished_reason(slot),
            prompt_len=slot.prompt_len,
            submit_time=slot.submit_time,
            finish_time=self._now(),
            first_token_time=slot.first_token_time)


def make_requests(batch: dict, max_new_tokens: int,
                  key: jax.Array | None = None, temperature: float = 0.0,
                  top_k: int = 0, priority: int = 0,
                  deadline_s: float | None = None) -> list[Request]:
    """Split a pre-batched input dict (engine.generate contract) into one
    Request per row; row index becomes the uid.  The batch-level sampling
    params become per-request params; ``priority``/``deadline_s`` apply
    uniformly to every row."""
    arrays = {k: v for k, v in batch.items() if k != "cache_len"}
    B = arrays["tokens"].shape[0]
    out = []
    for b in range(B):
        out.append(Request(
            uid=b,
            inputs={k: v[b:b + 1] for k, v in arrays.items()},
            max_new_tokens=max_new_tokens,
            key=None if key is None else jax.random.fold_in(key, b),
            temperature=temperature, top_k=top_k,
            priority=priority, deadline_s=deadline_s))
    return out
