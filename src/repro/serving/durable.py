"""Write-ahead request journal + durable restart pipeline (DESIGN.md §13).

The scheduler's snapshot (`serving.faults.save_snapshot`, a committed
generation of the `core.durable` store) is a *periodic* capture; the
journal makes the window between snapshots durable.  Every lifecycle
transition is appended — fsynced by default — *as it happens*:

    submit  — the full request payload (a submit is acknowledged once
              ``DurableScheduler.submit`` returns, i.e. after the fsync)
    retire  — the full :class:`FinishedRequest` (tokens, logprobs,
              finish_reason); covers EOS/length retirement, cancels,
              deadline expiry and ``max_new_tokens=0`` short-circuits
    cancel  — informational marker (the authoritative outcome is the
              retire record the cancel produced)

Records are JSON lines with a crc32; replay stops at the first torn or
corrupt record (an unacknowledged tail, the expected shape of a crash
mid-append) and recovery truncates the file there before appending.

Recovery (:meth:`DurableScheduler.recover`) =

    load the newest *clean* snapshot generation (checksummed; corrupt
    generations fall back, `core.durable.load_latest_good`)
  + replay every journal segment at or after that generation, in order:
      - submits of unknown uids re-enter the queue (same inputs, PRNG
        key, priority, submit time),
      - retire records are authoritative: the journaled result is kept
        verbatim, any live copy of the request is dropped (blocks freed)
        rather than recomputed,
  + commit a fresh snapshot generation so the next crash replays only
    its own window.

A clean shutdown (`serve.py` Ctrl-C) writes the same snapshot + journal
checkpoint, so crash and clean-stop share one recovery entry point
(``--restore``).  Survivor token streams are bit-identical to an
uninterrupted run: slot PRNG state rides in the snapshot, journaled
submits carry the request's own key, and decode is per-slot masked, so
batch composition never leaks between streams (PR 6 contract).
"""
from __future__ import annotations

import base64
import json
import os
import zlib

import jax.numpy as jnp
import numpy as np

from repro.core import durable
from .faults import load_snapshot, save_snapshot
from .scheduler import FinishedRequest, Request, Scheduler

JOURNAL_PREFIX = "journal"


# ------------------------------------------------------------- serialization
def _enc_arr(a) -> dict:
    a = np.asarray(a)
    return {"dtype": a.dtype.name, "shape": list(a.shape),
            "b64": base64.b64encode(
                np.ascontiguousarray(a).tobytes()).decode("ascii")}


def _dec_arr(d) -> np.ndarray:
    a = np.frombuffer(base64.b64decode(d["b64"]),
                      dtype=durable.resolve_dtype(d["dtype"]))
    return a.reshape(tuple(d["shape"])).copy()


def encode_request(req: Request) -> dict:
    return {"uid": req.uid,
            "inputs": {k: _enc_arr(v) for k, v in req.inputs.items()},
            "max_new_tokens": req.max_new_tokens,
            "key": None if req.key is None else _enc_arr(req.key),
            "temperature": req.temperature, "top_k": req.top_k,
            "priority": req.priority, "deadline_s": req.deadline_s}


def decode_request(d: dict) -> Request:
    return Request(
        uid=int(d["uid"]),
        inputs={k: jnp.asarray(_dec_arr(v)) for k, v in d["inputs"].items()},
        max_new_tokens=int(d["max_new_tokens"]),
        key=None if d["key"] is None else jnp.asarray(_dec_arr(d["key"])),
        temperature=float(d["temperature"]), top_k=int(d["top_k"]),
        priority=int(d["priority"]),
        deadline_s=(None if d["deadline_s"] is None
                    else float(d["deadline_s"])))


def encode_finished(f: FinishedRequest) -> dict:
    return {"uid": f.uid, "tokens": np.asarray(f.tokens).tolist(),
            "logprobs": [float(x) for x in np.asarray(f.logprobs)],
            "finish_reason": f.finish_reason, "prompt_len": f.prompt_len,
            "submit_time": f.submit_time, "finish_time": f.finish_time,
            "first_token_time": f.first_token_time}


def decode_finished(d: dict) -> FinishedRequest:
    return FinishedRequest(
        uid=int(d["uid"]), tokens=np.asarray(d["tokens"], np.int32),
        logprobs=np.asarray(d["logprobs"], np.float32),
        finish_reason=str(d["finish_reason"]),
        prompt_len=int(d["prompt_len"]),
        submit_time=float(d["submit_time"]),
        finish_time=float(d["finish_time"]),
        first_token_time=(None if d.get("first_token_time") is None
                          else float(d["first_token_time"])))


# ------------------------------------------------------------------ journal
class RequestJournal:
    """Append-only crc-checked JSON-lines journal.  ``fsync=True`` makes
    every append durable before it returns (the acknowledgement point);
    tests and benchmarks may turn it off."""

    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        self._f = open(path, "ab")
        self._seq = 0

    def append(self, rec: dict) -> None:
        rec = dict(rec, seq=self._seq)
        body = json.dumps(rec, sort_keys=True)
        rec["crc"] = zlib.crc32(body.encode())
        self._f.write((json.dumps(rec, sort_keys=True) + "\n").encode())
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self._seq += 1

    def close(self) -> None:
        self._f.close()

    @staticmethod
    def replay(path: str) -> tuple[list[dict], int]:
        """Read records until the first torn/corrupt line.  Returns
        (records, good_offset): everything at or past ``good_offset`` is
        an unacknowledged tail and must be truncated before appending."""
        records: list[dict] = []
        offset = 0
        if not os.path.exists(path):
            return records, offset
        with open(path, "rb") as f:
            for line in f:
                if not line.endswith(b"\n"):
                    break                 # torn tail: crash mid-append
                try:
                    rec = json.loads(line)
                    crc = rec.pop("crc")
                    body = json.dumps(rec, sort_keys=True)
                    if crc != zlib.crc32(body.encode()):
                        break
                except (json.JSONDecodeError, KeyError, TypeError,
                        UnicodeDecodeError):
                    break
                records.append(rec)
                offset += len(line)
        return records, offset


# ---------------------------------------------------------- durable wrapper
class DurableScheduler:
    """A :class:`Scheduler` with a durable shadow: submits/retires are
    journaled as they happen, snapshots are committed every
    ``snapshot_every`` decode steps (and on :meth:`checkpoint`), and
    :meth:`recover` rebuilds the whole serving state after a ``kill -9``.
    Everything not overridden here delegates to the wrapped scheduler."""

    def __init__(self, sched: Scheduler, root: str, *,
                 snapshot_every: int | None = None, fsync: bool = True,
                 keep_generations: int = 3):
        self.sched = sched
        self.root = root
        self.snapshot_every = snapshot_every
        self.fsync = fsync
        self.keep_generations = keep_generations
        os.makedirs(root, exist_ok=True)
        self._fin_mark = len(sched.finished)
        gens = durable.committed_generations(root)
        if gens:
            # attach to an existing store (in-memory restart, or recover):
            # continue the newest generation's journal segment
            self.generation = gens[-1]
            self.journal = RequestJournal(
                self._journal_path(self.generation), fsync)
            self._snap_steps = sched.steps_run
        else:
            # first boot: commit generation 1 now so recovery always has
            # a snapshot to anchor journal replay
            self.generation = 0
            self.journal = None
            self.checkpoint()

    def _journal_path(self, gen: int) -> str:
        return os.path.join(self.root, f"{JOURNAL_PREFIX}_{gen:08d}.log")

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request, submit_time: float | None = None) -> None:
        """Validate + enqueue, then journal.  Once this returns, the
        request survives a crash (fsynced submit record)."""
        self.sched.submit(req, submit_time)
        q = self.sched.queue[-1]
        self.journal.append({"type": "submit", "req": encode_request(req),
                             "submit_time": q.submit_time})

    def cancel(self, uid: int) -> bool:
        ok = self.sched.cancel(uid)
        if ok:
            self.journal.append({"type": "cancel", "uid": int(uid)})
        self._sync_finished()
        return ok

    def step(self):
        done = self.sched.step()
        self._sync_finished()
        if self.snapshot_every is not None and \
                self.sched.steps_run - self._snap_steps >= self.snapshot_every:
            self.checkpoint()
        return done

    def run(self) -> dict[int, FinishedRequest]:
        """Drain (same stall guard as ``Scheduler.run``), journaling every
        retirement and keeping the periodic snapshot cadence."""
        out: dict[int, FinishedRequest] = {}
        while not self.sched.idle:
            before = (len(self.sched.queue), self.sched.num_active,
                      self.sched.steps_run, len(self.sched.finished))
            for f in self.step():
                out[f.uid] = f
            after = (len(self.sched.queue), self.sched.num_active,
                     self.sched.steps_run, len(self.sched.finished))
            if before == after and after[1] == 0:
                raise RuntimeError(
                    f"scheduler stalled: {len(self.sched.queue)} queued "
                    f"requests, no active slots, and a step made no "
                    f"progress")
        return out

    def _sync_finished(self) -> None:
        for f in self.sched.finished[self._fin_mark:]:
            self.journal.append({"type": "retire",
                                 "fin": encode_finished(f)})
        self._fin_mark = len(self.sched.finished)

    # ----------------------------------------------------------- durability
    def checkpoint(self) -> int:
        """Commit a snapshot generation and rotate the journal: records
        before this point are superseded (older segments are kept on disk
        so a corrupt generation can still fall back and replay forward)."""
        if self.journal is not None:
            self._sync_finished()
            self.journal.close()
        save_snapshot(self.root, self.sched.snapshot())
        self.generation = durable.committed_generations(self.root)[-1]
        self.journal = RequestJournal(
            self._journal_path(self.generation), self.fsync)
        self._snap_steps = self.sched.steps_run
        if self.keep_generations:
            durable.prune_generations(self.root,
                                      keep=self.keep_generations)
            self._prune_journals()
        return self.generation

    def _prune_journals(self) -> None:
        live = set(durable.committed_generations(self.root))
        live.add(self.generation)
        for name in os.listdir(self.root):
            if not name.startswith(JOURNAL_PREFIX + "_"):
                continue
            g = int(name[len(JOURNAL_PREFIX) + 1:].split(".")[0])
            if g < min(live):
                os.unlink(os.path.join(self.root, name))

    def close(self) -> None:
        if self.journal is not None:
            self._sync_finished()
            self.journal.close()
            self.journal = None

    @classmethod
    def recover(cls, root: str, model, params, *, clock=None,
                rebase_clock: bool = False,
                snapshot_every: int | None = None, fsync: bool = True,
                log=None) -> "DurableScheduler":
        """Rebuild after a crash (or clean stop): newest clean snapshot
        generation + ordered replay of every journal segment at or after
        it, then a fresh checkpoint.  Corrupt generations are skipped
        (checksummed fallback); a torn journal tail is truncated."""
        gen, snap = _load_good_snapshot(root, log)
        sched = Scheduler.from_snapshot(model, params, snap, clock=clock,
                                        rebase_clock=rebase_clock)
        segments = sorted(
            (int(n[len(JOURNAL_PREFIX) + 1:].split(".")[0]),
             os.path.join(root, n))
            for n in os.listdir(root)
            if n.startswith(JOURNAL_PREFIX + "_") and n.endswith(".log"))
        replayed = 0
        for g, path in segments:
            if g < gen:
                continue
            records, good = RequestJournal.replay(path)
            size = os.path.getsize(path)
            if good < size:               # torn tail: unacknowledged bytes
                with open(path, "r+b") as f:
                    f.truncate(good)
                if log:
                    log(f"journal {path}: truncated torn tail "
                        f"({size - good} bytes)")
            for rec in records:
                _apply_record(sched, rec)
                replayed += 1
        if log:
            log(f"recovered from {root}: generation {gen}, "
                f"{replayed} journal records replayed "
                f"({len(sched.queue)} queued, {sched.num_active} active, "
                f"{len(sched.finished)} finished)")
        ds = cls(sched, root, snapshot_every=snapshot_every, fsync=fsync)
        ds.checkpoint()                   # bound the next crash's replay
        return ds

    # everything else — stats, resize, snapshot, idle, queue, allocator,
    # counters — reads/acts straight through to the wrapped scheduler
    def __getattr__(self, name):
        return getattr(self.sched, name)

    @property
    def hold_admissions(self) -> bool:
        return self.sched.hold_admissions

    @hold_admissions.setter
    def hold_admissions(self, v: bool) -> None:
        self.sched.hold_admissions = v


def _load_good_snapshot(root: str, log=None) -> tuple[int, dict]:
    gen, _tree, _arrays, _manifest, skipped = durable.load_latest_good(root)
    if skipped and log:
        for msg in skipped:
            log(f"skipped corrupt generation: {msg}")
    return gen, load_snapshot(root, generation=gen)


def _known_uids(sched: Scheduler) -> set[int]:
    uids = {f.uid for f in sched.finished}
    uids.update(q.req.uid for q in sched.queue)
    uids.update(s.uid for s in sched.slots if s is not None)
    return uids


def _apply_record(sched: Scheduler, rec: dict) -> None:
    t = rec.get("type")
    if t == "submit":
        req = decode_request(rec["req"])
        if req.uid not in _known_uids(sched):
            sched.submit(req, submit_time=float(rec["submit_time"]))
    elif t == "retire":
        fin = decode_finished(rec["fin"])
        if any(f.uid == fin.uid for f in sched.finished):
            return
        # the journaled result is authoritative (it was acknowledged):
        # drop any live copy instead of recomputing it
        sched.drop(fin.uid)
        sched.finished.append(fin)
        if fin.finish_reason == "cancelled":
            sched.cancelled += 1
        elif fin.finish_reason == "deadline":
            sched.expired += 1
    # "cancel" records are informational: the retire record that the
    # cancel produced carries the acknowledged outcome
