"""Host-side block-paged KV-cache management (DESIGN.md §7).

The device side is a set of *arenas* — one per cache leaf, shaped
``[layers, num_blocks + 1, block, ...]`` — plus a per-slot *block table*
``[num_slots, max_blocks] int32`` mapping logical token-blocks to arena
blocks (the extra arena block is a write sentinel: inactive slots and
unallocated table entries point at it, so masked decode steps and splice
padding never touch live storage).  This module owns everything the device
does NOT see: the free list, per-block reference counts, the content-hash
registry that enables prefix sharing, the LRU of retired-but-still-cached
blocks, and copy-on-write bookkeeping.

Every block is in exactly one of three states:

  free       — on the free list, content meaningless
  live       — refcount > 0; owned by one or more slots' block tables
  evictable  — refcount == 0 but *published* (content-hashed): the block
               still holds a reusable prompt prefix and is only reclaimed
               (LRU) when the free list runs dry

Prefix sharing is full-block granular: a block is published under the
chained hash of every token up to and including its own
(``chain_hashes``), so a hash hit guarantees the whole token prefix
matches, not just the block's own span.  Shared blocks are immutable —
a slot that must write into a shared (or published) block first asks
``cow()`` for a private replacement and the device copies content through
the gather(src-table)/scatter(dst-table) resume-prefill path.
"""
from __future__ import annotations

import collections
import hashlib

import numpy as np


def logical_blocks(n_tokens: int, block: int) -> int:
    """Number of fixed-size blocks covering ``n_tokens`` positions."""
    if n_tokens < 0:
        raise ValueError("n_tokens must be >= 0")
    return -(-n_tokens // block)


def chain_hashes(tokens, block: int) -> list[bytes]:
    """Chained content hash of every *full* block of a token sequence.

    ``hashes[i]`` digests tokens ``[0, (i+1)*block)`` — a match therefore
    certifies the entire prefix, which is what makes full blocks safely
    shareable between requests."""
    toks = np.ascontiguousarray(np.asarray(tokens, np.int64).reshape(-1))
    out: list[bytes] = []
    h = b""
    for i in range(len(toks) // block):
        h = hashlib.sha256(
            h + toks[i * block:(i + 1) * block].tobytes()).digest()
        out.append(h)
    return out


class BlockAllocator:
    """Refcounted fixed-size block allocator with a content-hash registry.

    The device sentinel block is NOT managed here — the allocator hands out
    ids in ``[0, num_blocks)`` and the arenas are sized ``num_blocks + 1``.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError("num_blocks must be >= 1")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block = block_size
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self._ref = np.zeros(num_blocks, np.int64)
        self._hash_of: dict[int, bytes] = {}      # published block -> hash
        self._by_hash: dict[bytes, int] = {}      # hash -> published block
        # refcount-0 published blocks, LRU order (oldest first)
        self._evictable: collections.OrderedDict[int, None] = \
            collections.OrderedDict()
        # pending-shrink fence: when set, ids >= _target are never handed
        # out again; live ones drain through decref and the shrink
        # completes via finalize_shrink() (the scheduler slices the device
        # arenas in the same breath)
        self._target: int | None = None
        # fault injection (serving.faults.FaultPlan): while set, the
        # allocator reports zero availability and prefix lookups miss, so
        # admissions defer exactly as under real pool exhaustion — no
        # mid-admission exception, nothing to roll back
        self.refuse_fresh = False

    # ------------------------------------------------------------- accounting
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def cached_count(self) -> int:
        return len(self._evictable)

    @property
    def available(self) -> int:
        """Blocks an ``alloc()`` can currently produce (free + evictable).
        Zero while fault injection refuses fresh allocations."""
        if self.refuse_fresh:
            return 0
        return len(self._free) + len(self._evictable)

    @property
    def in_use(self) -> int:
        return int(np.count_nonzero(self._ref))

    @property
    def capacity(self) -> int:
        """Admission-visible pool size: the pending-shrink target when a
        resize is draining, else ``num_blocks`` — requests sized against
        the old capacity could never be admitted after the shrink lands."""
        return self._target if self._target is not None else self.num_blocks

    @property
    def pending_target(self) -> int | None:
        return self._target

    @property
    def shrink_ready(self) -> bool:
        """True when a pending shrink has drained (no live block at or
        above the fence) and :meth:`finalize_shrink` may run."""
        return (self._target is not None
                and not np.any(self._ref[self._target:]))

    def assert_quiescent(self) -> None:
        """Leak check for a drained scheduler: every block must be either
        free or retired-but-cached (refcount 0, published), and the three
        states must tile the pool exactly.  Raises AssertionError with the
        full ledger on any leak."""
        leaked = [int(b) for b in np.nonzero(self._ref)[0]]
        if leaked:
            raise AssertionError(
                f"leaked blocks (refcount > 0 after drain): "
                f"{[(b, int(self._ref[b])) for b in leaked]}")
        if self._free and min(self._free) < 0:
            raise AssertionError("negative id on the free list")
        if self.free_count + self.cached_count != self.num_blocks:
            raise AssertionError(
                f"block ledger does not tile the pool: free={self.free_count}"
                f" cached={self.cached_count} != total={self.num_blocks}")

    def refcount(self, bid: int) -> int:
        self._check(bid)
        return int(self._ref[bid])

    def _check(self, bid: int) -> None:
        if not 0 <= bid < self.num_blocks:
            raise ValueError(f"block id {bid} out of range")

    # ------------------------------------------------------------- lifecycle
    def alloc(self) -> int:
        """Take a private block (refcount 1), evicting the LRU published
        block if the free list is dry."""
        if self.refuse_fresh:
            raise RuntimeError("allocation refused (fault injection) — "
                               "admission must defer, not alloc")
        if self._free:
            bid = self._free.pop()
        elif self._evictable:
            bid, _ = self._evictable.popitem(last=False)
            del self._by_hash[self._hash_of.pop(bid)]
        else:
            raise RuntimeError("out of KV-cache blocks")
        self._ref[bid] = 1
        return bid

    def incref(self, bid: int) -> None:
        self._check(bid)
        if self._ref[bid] == 0:
            if bid not in self._evictable:
                raise RuntimeError(f"incref of free block {bid}")
            del self._evictable[bid]      # revived from the retired cache
        self._ref[bid] += 1

    def decref(self, bid: int) -> None:
        self._check(bid)
        if self._ref[bid] <= 0:
            raise RuntimeError(f"double free of block {bid}")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            if self._target is not None and bid >= self._target:
                # draining a pending shrink: the id dies here instead of
                # returning to circulation
                if bid in self._hash_of:
                    del self._by_hash[self._hash_of.pop(bid)]
                return
            if bid in self._hash_of:      # published: keep content, evict LRU
                self._evictable[bid] = None
            else:
                self._free.append(bid)

    # --------------------------------------------------------- prefix registry
    def publish(self, bid: int, h: bytes) -> int:
        """Register a live block's content hash for sharing.  First writer
        wins: if the hash is already mapped (another block holds identical
        content, e.g. a COW copy) the existing mapping is kept and its
        block id returned."""
        self._check(bid)
        if self._ref[bid] <= 0:
            raise RuntimeError(f"publish of non-live block {bid}")
        if h in self._by_hash:
            return self._by_hash[h]
        if bid in self._hash_of:          # re-publish under a new hash
            del self._by_hash[self._hash_of[bid]]
        self._hash_of[bid] = h
        self._by_hash[h] = bid
        return bid

    def lookup(self, h: bytes) -> int | None:
        """Non-acquiring probe (no refcount change)."""
        return self._by_hash.get(h)

    def acquire(self, h: bytes) -> int | None:
        """Look a hash up and take a reference (reviving an evictable
        block).  Returns None on miss — including while fault injection
        refuses allocations, so an admission under injection defers
        cleanly instead of reaching ``cow()``/``alloc()``."""
        if self.refuse_fresh:
            return None
        bid = self._by_hash.get(h)
        if bid is None:
            return None
        self.incref(bid)
        return bid

    def cow(self, bid: int) -> int:
        """Copy-on-write: called by an owner about to *write into* logical
        content currently stored in ``bid``.  If the block is exclusively
        owned and unpublished the write is safe in place and ``bid`` is
        returned unchanged; otherwise a fresh private block is allocated,
        the caller's reference on ``bid`` is dropped, and the new id is
        returned (the device copies content via gather-src/scatter-dst)."""
        self._check(bid)
        if self._ref[bid] <= 0:
            raise RuntimeError(f"cow of non-live block {bid}")
        if self._ref[bid] == 1 and bid not in self._hash_of:
            return bid
        new = self.alloc()
        self.decref(bid)
        return new

    # ---------------------------------------------------------------- resize
    def resize(self, num_blocks: int) -> bool:
        """Live-resize the pool (Scheduler.resize drives this and reshapes
        the device arenas to match).  Growth applies immediately: new ids
        join the free list.  A shrink drops free and evictable ids at or
        above the new target at once and *fences* the rest — live blocks
        above the target drain through their normal decrefs and are never
        re-issued; call :meth:`finalize_shrink` once :attr:`shrink_ready`.
        Returns True when the resize is fully applied."""
        if num_blocks < 1:
            raise ValueError("num_blocks must be >= 1")
        if num_blocks >= self.num_blocks:
            if num_blocks > self.num_blocks:          # grow
                # append descending so pops hand out ascending ids, matching
                # the construction-time order
                self._free.extend(
                    range(num_blocks - 1, self.num_blocks - 1, -1))
                self._ref = np.concatenate(
                    [self._ref,
                     np.zeros(num_blocks - self.num_blocks, np.int64)])
                self.num_blocks = num_blocks
            if self._target is not None:
                # cancelling a pending shrink: ids dropped while the fence
                # was up (filtered free ids, decref'd-dead ids) return to
                # circulation so the ledger tiles the pool again
                have = (set(self._free) | set(self._evictable)
                        | {int(b) for b in np.nonzero(self._ref)[0]})
                self._free.extend(sorted(
                    set(range(self.num_blocks)) - have, reverse=True))
                self._target = None
            return True
        self._target = num_blocks
        self._free = [b for b in self._free if b < num_blocks]
        for bid in [b for b in self._evictable if b >= num_blocks]:
            del self._evictable[bid]
            del self._by_hash[self._hash_of.pop(bid)]
        if self.shrink_ready:
            self.finalize_shrink()
            return True
        return False

    def finalize_shrink(self) -> None:
        """Complete a drained shrink: truncate the refcount ledger to the
        fence.  The caller owns slicing the device arenas in lockstep."""
        if self._target is None:
            return
        if not self.shrink_ready:
            live = np.nonzero(self._ref[self._target:])[0] + self._target
            raise RuntimeError(
                f"shrink to {self._target} not drained: live ids "
                f"{[int(b) for b in live]}")
        self._ref = self._ref[:self._target]
        self.num_blocks = self._target
        self._target = None

    # -------------------------------------------------------------- snapshot
    def state(self) -> dict:
        """JSON-safe snapshot of the full ledger (free-list order and LRU
        order preserved — restore is deterministic)."""
        return {
            "num_blocks": self.num_blocks, "block": self.block,
            "free": [int(b) for b in self._free],
            "ref": [int(r) for r in self._ref],
            "published": [[int(b), h.hex()] for b, h in self._hash_of.items()],
            "evictable": [int(b) for b in self._evictable],
            "target": self._target,
        }

    @classmethod
    def from_state(cls, st: dict) -> "BlockAllocator":
        a = cls(int(st["num_blocks"]), int(st["block"]))
        a._free = [int(b) for b in st["free"]]
        a._ref = np.asarray(st["ref"], np.int64)
        a._hash_of = {int(b): bytes.fromhex(h) for b, h in st["published"]}
        a._by_hash = {h: b for b, h in a._hash_of.items()}
        a._evictable = collections.OrderedDict(
            (int(b), None) for b in st["evictable"])
        a._target = None if st.get("target") is None else int(st["target"])
        return a
