"""Host-side block-paged KV-cache management (DESIGN.md §7).

The device side is a set of *arenas* — one per cache leaf, shaped
``[layers, num_blocks + 1, block, ...]`` — plus a per-slot *block table*
``[num_slots, max_blocks] int32`` mapping logical token-blocks to arena
blocks (the extra arena block is a write sentinel: inactive slots and
unallocated table entries point at it, so masked decode steps and splice
padding never touch live storage).  This module owns everything the device
does NOT see: the free list, per-block reference counts, the content-hash
registry that enables prefix sharing, the LRU of retired-but-still-cached
blocks, and copy-on-write bookkeeping.

Every block is in exactly one of three states:

  free       — on the free list, content meaningless
  live       — refcount > 0; owned by one or more slots' block tables
  evictable  — refcount == 0 but *published* (content-hashed): the block
               still holds a reusable prompt prefix and is only reclaimed
               (LRU) when the free list runs dry

Prefix sharing is full-block granular: a block is published under the
chained hash of every token up to and including its own
(``chain_hashes``), so a hash hit guarantees the whole token prefix
matches, not just the block's own span.  Shared blocks are immutable —
a slot that must write into a shared (or published) block first asks
``cow()`` for a private replacement and the device copies content through
the gather(src-table)/scatter(dst-table) resume-prefill path.
"""
from __future__ import annotations

import collections
import hashlib

import numpy as np


def logical_blocks(n_tokens: int, block: int) -> int:
    """Number of fixed-size blocks covering ``n_tokens`` positions."""
    if n_tokens < 0:
        raise ValueError("n_tokens must be >= 0")
    return -(-n_tokens // block)


def chain_hashes(tokens, block: int) -> list[bytes]:
    """Chained content hash of every *full* block of a token sequence.

    ``hashes[i]`` digests tokens ``[0, (i+1)*block)`` — a match therefore
    certifies the entire prefix, which is what makes full blocks safely
    shareable between requests."""
    toks = np.ascontiguousarray(np.asarray(tokens, np.int64).reshape(-1))
    out: list[bytes] = []
    h = b""
    for i in range(len(toks) // block):
        h = hashlib.sha256(
            h + toks[i * block:(i + 1) * block].tobytes()).digest()
        out.append(h)
    return out


class BlockAllocator:
    """Refcounted fixed-size block allocator with a content-hash registry.

    The device sentinel block is NOT managed here — the allocator hands out
    ids in ``[0, num_blocks)`` and the arenas are sized ``num_blocks + 1``.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError("num_blocks must be >= 1")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block = block_size
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self._ref = np.zeros(num_blocks, np.int64)
        self._hash_of: dict[int, bytes] = {}      # published block -> hash
        self._by_hash: dict[bytes, int] = {}      # hash -> published block
        # refcount-0 published blocks, LRU order (oldest first)
        self._evictable: collections.OrderedDict[int, None] = \
            collections.OrderedDict()

    # ------------------------------------------------------------- accounting
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def cached_count(self) -> int:
        return len(self._evictable)

    @property
    def available(self) -> int:
        """Blocks an ``alloc()`` can currently produce (free + evictable)."""
        return len(self._free) + len(self._evictable)

    @property
    def in_use(self) -> int:
        return int(np.count_nonzero(self._ref))

    def refcount(self, bid: int) -> int:
        self._check(bid)
        return int(self._ref[bid])

    def _check(self, bid: int) -> None:
        if not 0 <= bid < self.num_blocks:
            raise ValueError(f"block id {bid} out of range")

    # ------------------------------------------------------------- lifecycle
    def alloc(self) -> int:
        """Take a private block (refcount 1), evicting the LRU published
        block if the free list is dry."""
        if self._free:
            bid = self._free.pop()
        elif self._evictable:
            bid, _ = self._evictable.popitem(last=False)
            del self._by_hash[self._hash_of.pop(bid)]
        else:
            raise RuntimeError("out of KV-cache blocks")
        self._ref[bid] = 1
        return bid

    def incref(self, bid: int) -> None:
        self._check(bid)
        if self._ref[bid] == 0:
            if bid not in self._evictable:
                raise RuntimeError(f"incref of free block {bid}")
            del self._evictable[bid]      # revived from the retired cache
        self._ref[bid] += 1

    def decref(self, bid: int) -> None:
        self._check(bid)
        if self._ref[bid] <= 0:
            raise RuntimeError(f"double free of block {bid}")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            if bid in self._hash_of:      # published: keep content, evict LRU
                self._evictable[bid] = None
            else:
                self._free.append(bid)

    # --------------------------------------------------------- prefix registry
    def publish(self, bid: int, h: bytes) -> int:
        """Register a live block's content hash for sharing.  First writer
        wins: if the hash is already mapped (another block holds identical
        content, e.g. a COW copy) the existing mapping is kept and its
        block id returned."""
        self._check(bid)
        if self._ref[bid] <= 0:
            raise RuntimeError(f"publish of non-live block {bid}")
        if h in self._by_hash:
            return self._by_hash[h]
        if bid in self._hash_of:          # re-publish under a new hash
            del self._by_hash[self._hash_of[bid]]
        self._hash_of[bid] = h
        self._by_hash[h] = bid
        return bid

    def lookup(self, h: bytes) -> int | None:
        """Non-acquiring probe (no refcount change)."""
        return self._by_hash.get(h)

    def acquire(self, h: bytes) -> int | None:
        """Look a hash up and take a reference (reviving an evictable
        block).  Returns None on miss."""
        bid = self._by_hash.get(h)
        if bid is None:
            return None
        self.incref(bid)
        return bid

    def cow(self, bid: int) -> int:
        """Copy-on-write: called by an owner about to *write into* logical
        content currently stored in ``bid``.  If the block is exclusively
        owned and unpublished the write is safe in place and ``bid`` is
        returned unchanged; otherwise a fresh private block is allocated,
        the caller's reference on ``bid`` is dropped, and the new id is
        returned (the device copies content via gather-src/scatter-dst)."""
        self._check(bid)
        if self._ref[bid] <= 0:
            raise RuntimeError(f"cow of non-live block {bid}")
        if self._ref[bid] == 1 and bid not in self._hash_of:
            return bid
        new = self.alloc()
        self.decref(bid)
        return new
