"""Atomic checkpointing of arbitrary pytrees (params + optimizer + data
iterator state).

Format: one ``.npz`` of flattened leaves (keyed by path) + a msgpack
manifest (step, tree structure hash, wallclock).  Writes go to a temp dir
and are renamed into place — a torn write can never be restored.  On real
clusters only process 0 writes (``jax.process_index() == 0``); restores are
collective reads of the same file.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def tree_fingerprint(tree) -> str:
    keys = sorted(_flatten_structure(tree))
    return hashlib.sha256("|".join(keys).encode()).hexdigest()[:16]


def _flatten_structure(tree) -> list[str]:
    return [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        + f":{leaf.shape}:{leaf.dtype}"
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


def save(path: str, tree, step: int, extra: dict | None = None) -> str:
    """Atomic save.  Returns the final checkpoint directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + f".tmp.{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {"step": step, "time": time.time(),
                "fingerprint": tree_fingerprint(tree),
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def available_steps(path: str) -> list[int]:
    if not os.path.isdir(path):
        return []
    out = []
    for name in os.listdir(path):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and "tmp" not in name:
            if os.path.exists(os.path.join(path, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def restore(path: str, template, step: int | None = None,
            shardings=None) -> tuple[object, dict]:
    """Restore into the structure of ``template``; verifies fingerprint.
    ``shardings``: optional matching tree of NamedShardings — restoring onto
    a *different* mesh than the one that saved is the elastic-rescale path
    (fault.py)."""
    steps = available_steps(path)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {path}")
    step = steps[-1] if step is None else step
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest["fingerprint"] != tree_fingerprint(template):
        raise ValueError("checkpoint/tree structure mismatch "
                         f"({manifest['fingerprint']})")
    arrays = np.load(os.path.join(d, "arrays.npz"))
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(flat_t))
    for (path_t, leaf), shard in zip(flat_t, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_t)
        arr = arrays[key]
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def prune(path: str, keep: int = 3):
    for step in available_steps(path)[:-keep]:
        shutil.rmtree(os.path.join(path, f"step_{step:08d}"),
                      ignore_errors=True)
