"""Atomic, checksummed checkpointing of arbitrary pytrees (params +
optimizer + data iterator state).

Format (PR 8, DESIGN.md §13): one ``step_<NNNNNNNN>`` directory per save
holding chunk-streamed ``arrays.bin`` + a JSON manifest (step, tree
structure hash, wallclock, and a per-array index with dtype/shape/offset/
crc32), written with the ``core.durable`` commit protocol — temp dir,
fsync of every file, atomic rename, parent-dir fsync — so a torn write
can never be restored.  Restores verify every checksum while streaming;
a truncated or bit-flipped checkpoint raises a clear ``RuntimeError``
naming the file and the remaining good steps, and ``restore(step=None)``
falls back to the newest step that loads clean.  Pre-PR-8 checkpoints
(``arrays.npz``) are still readable.  On real clusters only process 0
writes (``jax.process_index() == 0``); restores are collective reads of
the same files.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
import zipfile

import jax
import numpy as np

from repro.core import durable


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def tree_fingerprint(tree) -> str:
    keys = sorted(_flatten_structure(tree))
    return hashlib.sha256("|".join(keys).encode()).hexdigest()[:16]


def _flatten_structure(tree) -> list[str]:
    return [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        + f":{leaf.shape}:{leaf.dtype}"
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


def save(path: str, tree, step: int, extra: dict | None = None,
         quantize_tt: bool = False) -> str:
    """Atomic checksummed save.  Returns the final checkpoint directory.

    ``quantize_tt=True`` quantizes every TT core bundle on the way out
    (int8 cores + per-layer/per-expert fp32 scales, ``core.quant`` via
    ``models.layers.quantize_tt_params``) — the serving-ready checkpoint
    transform of DESIGN.md §8 applied at save time instead of load time,
    so the int8 artifact on disk is bit-identical to
    ``Model.quantize_params`` of the fp32 tree and restores into the
    int8-resident kernel path with no further transform.  The manifest
    fingerprint is taken over the *transformed* tree (int8 shapes +
    ``scales`` leaves) and ``extra["quantized_tt"]`` records the
    transform; restore with a quantized template.  Idempotent: a tree
    whose cores are already int8 is written unchanged.  For serving
    checkpoints (a params tree, or ``{"params": ...}``): optimizer
    moments mirror the params structure, so a full train state would get
    its ``tt`` moment bundles quantized too — save those without the
    flag."""
    if quantize_tt:
        from repro.models.layers import quantize_tt_params
        tree = quantize_tt_params(tree)
        extra = dict(extra or {}, quantized_tt=True)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + f".tmp.{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        index = durable.write_arrays(tmp, _flatten(tree))
        manifest = {"schema": durable.DURABLE_SCHEMA, "step": step,
                    "time": time.time(),
                    "fingerprint": tree_fingerprint(tree),
                    "arrays": index, "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    durable.fsync_dir(path)
    return final


def available_steps(path: str) -> list[int]:
    if not os.path.isdir(path):
        return []
    out = []
    for name in os.listdir(path):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and "tmp" not in name:
            if os.path.exists(os.path.join(path, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def _load_step_arrays(path: str, step: int) -> tuple[dict, dict]:
    """Load one step's (manifest, arrays-by-key), verifying checksums.
    Raises RuntimeError naming the damaged file and the other steps that
    are still available."""
    d = os.path.join(path, f"step_{step:08d}")

    def _bad(detail: str) -> RuntimeError:
        good = [s for s in available_steps(path) if s != step]
        return RuntimeError(
            f"checkpoint step {step} at {d} is corrupt: {detail}; "
            + (f"good steps still available: {good} — pass step= to "
               f"restore one of them" if good
               else "no other checkpoint steps are available"))

    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise _bad(f"unreadable manifest ({e})") from e
    if "arrays" in manifest:                      # current chunked format
        try:
            arrays = durable.read_arrays(os.path.join(d, "arrays.bin"),
                                         manifest["arrays"])
        except durable.CorruptGenerationError as e:
            raise _bad(str(e)) from e
        return manifest, arrays
    # pre-PR-8 format: a single numpy archive, no checksums — corruption
    # still surfaces as a named RuntimeError, not a raw zipfile error
    npz_path = os.path.join(d, "arrays.npz")
    try:
        with np.load(npz_path) as npz:
            arrays = {k: npz[k] for k in npz.files}
    except (zipfile.BadZipFile, ValueError, OSError, EOFError) as e:
        raise _bad(f"legacy archive {npz_path} truncated or damaged "
                   f"({e})") from e
    return manifest, arrays


def restore(path: str, template, step: int | None = None,
            shardings=None) -> tuple[object, dict]:
    """Restore into the structure of ``template``; verifies the structure
    fingerprint and every array checksum.  ``step=None`` restores the
    newest step that loads *clean* — corrupt newer steps are skipped with
    the reasons attached to the error if nothing survives.  An explicit
    ``step`` never falls back.  ``shardings``: optional matching tree of
    NamedShardings — restoring onto a *different* mesh than the one that
    saved is the elastic-rescale path (fault.py)."""
    steps = available_steps(path)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {path}")
    if step is not None:
        manifest, arrays = _load_step_arrays(path, step)
    else:
        errors: list[str] = []
        for s in reversed(steps):
            try:
                manifest, arrays = _load_step_arrays(path, s)
                break
            except RuntimeError as e:
                errors.append(str(e))
        else:
            raise RuntimeError(
                f"every checkpoint under {path} is corrupt:\n  "
                + "\n  ".join(errors))
    if manifest["fingerprint"] != tree_fingerprint(template):
        raise ValueError("checkpoint/tree structure mismatch "
                         f"({manifest['fingerprint']})")
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(flat_t))
    for (path_t, leaf), shard in zip(flat_t, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_t)
        arr = arrays[key]
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def prune(path: str, keep: int = 3):
    for step in available_steps(path)[:-keep]:
        shutil.rmtree(os.path.join(path, f"step_{step:08d}"),
                      ignore_errors=True)
