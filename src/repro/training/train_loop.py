"""Train step assembly: mixed precision, microbatch accumulation, optional
gradient compression; data parallelism is expressed through shardings and
realized by GSPMD (pjit), so one function serves 1 chip and 512 chips.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.models.spec import cast_tree
from .compression import ef_compress_tree, ef_init
from .optimizer import OptConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    micro_batches: int = 1
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    grad_compression: bool = False


def init_train_state(model: Model, key: jax.Array,
                     tcfg: TrainConfig) -> dict:
    params = model.init(key)
    state = {"params": params, "opt": adamw_init(params)}
    if tcfg.grad_compression:
        state["ef"] = ef_init(params)
    return state


def make_train_step(model: Model, tcfg: TrainConfig):
    """Returns train_step(state, batch) → (state, metrics).  Pure function
    of its arguments — safe to jit/pjit with donated state."""

    def loss_fn(params, batch):
        cparams = cast_tree(params, tcfg.compute_dtype)
        return model.loss(cparams, batch, remat=tcfg.remat)

    def grads_of(params, batch):
        if tcfg.micro_batches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        # microbatch accumulation over the leading batch axis
        mb = tcfg.micro_batches
        split = jax.tree.map(
            lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch)

        def body(acc, micro):
            l, g = jax.value_and_grad(loss_fn)(params, micro)
            return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g)), None

        zero = (jnp.zeros(()),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params))
        (l, g), _ = jax.lax.scan(body, zero, split)
        scale = 1.0 / mb
        return l * scale, jax.tree.map(lambda x: x * scale, g)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        loss, grads = grads_of(params, batch)
        new_state = dict(state)
        if tcfg.grad_compression:
            grads, new_state["ef"] = ef_compress_tree(grads, state["ef"])
        new_params, new_opt, metrics = adamw_update(
            grads, state["opt"], params, tcfg.opt)
        new_state.update(params=new_params, opt=new_opt)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step
