"""Gradient compression for the slow (cross-pod) hop.

int8 uniform quantization with error feedback: the quantization residual is
carried in an fp32 state and added back before the next step's quantization,
so the scheme is unbiased over time (1-bit-Adam family result).

Not to be confused with *weight* compression: TT factorization of the
weights and its accuracy-recovery finetune live in ``core.tt`` /
``training/finetune.py`` (the DSE study's rank-adaptive finetune stage,
DESIGN.md §12).  This module only touches gradients on the wire.

Two integration points:

* ``ef_compress_tree`` — quantize/dequantize grads inside the train step
  (models the wire format; used by default so the numerics are always
  exercised, hardware or not).
* ``compressed_psum`` — a shard_map collective that actually moves int8
  across the 'pod' mesh axis: quantize → all_gather(int8) → dequant-sum.
  Cross-pod bytes drop 4× vs fp32 (2× vs bf16); the intra-pod reduction
  stays full precision.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def ef_compress_tree(grads, ef_state):
    """Error-feedback int8 round-trip on every gradient leaf.
    Returns (compressed-then-restored grads, new ef state)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize(target)
        restored = dequantize(q, s)
        return restored.astype(g.dtype), target - restored
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Quantized sum across one mesh axis (inside shard_map): each member
    contributes an int8 tensor + fp32 scale; the sum is done after dequant
    so precision loss is bounded by one quantization per member."""
    q, s = quantize(x.astype(jnp.float32))
    qs = jax.lax.all_gather(q, axis_name)            # [n, ...] int8 on wire
    ss = jax.lax.all_gather(s, axis_name)            # [n] fp32 (negligible)
    return jnp.tensordot(ss, qs.astype(jnp.float32), axes=1)
