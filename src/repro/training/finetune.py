"""Rank-adaptive TT finetune: train the TT cores only, backbone frozen.

The DSE study (core/study.py, DESIGN.md §12) evaluates candidate TT plans
end-to-end; a near-miss plan — slightly over the quality gate's perplexity
budget — can often buy back the gap with a few dozen gradient steps on the
cores alone, which is cheap because the cores are the *compressed*
parameterization (that is the paper's whole point).  This module provides
that loop:

* ``tt_params_from_dense`` — initialize a TT twin's cores by TT-SVD of the
  dense reference weights (``core.tt.tt_decompose`` per stacked layer
  slice), so the twin starts as the best rank-r approximation rather than
  at random.
* ``split_tt`` / ``merge_tt`` — partition a parameter tree into the TT-core
  subtree (trainable) and everything else (frozen).  The optimizer only
  ever sees the TT subtree: freezing by tree-split, not by grad-zeroing,
  so AdamW weight decay cannot silently erode the "frozen" backbone.
* ``finetune_tt`` — the short finetune driver (jitted step, deterministic
  batch schedule, loss history out).

Distinct from ``training/compression.py``, which is *gradient* compression
(int8 error-feedback for the cross-pod hop) — that module is about wire
bytes during training; this one is about recovering model quality after
weight-space TT compression.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tt import TTPlan, tt_decompose
from repro.models.model import Model
from .optimizer import OptConfig, adamw_init, adamw_update


# ---------------------------------------------------------------------------
# Tree surgery
# ---------------------------------------------------------------------------

def split_tt(params: dict) -> tuple[dict, dict]:
    """Partition ``params`` into (tt_subtree, frozen_rest).

    The tt_subtree keeps only branches that lead to a ``"tt"`` core
    bundle (preserving the path structure so ``merge_tt`` can overlay it
    back); the rest tree holds every other leaf — dense weights, norms,
    embeddings, biases."""
    def walk(node: dict) -> tuple[dict, dict]:
        tt: dict = {}
        rest: dict = {}
        for k, v in node.items():
            if k == "tt" and isinstance(v, dict):
                tt[k] = v
            elif isinstance(v, dict):
                t, r = walk(v)
                if t:
                    tt[k] = t
                rest[k] = r
            else:
                rest[k] = v
        return tt, rest
    return walk(params)


def merge_tt(tt: dict, rest: dict) -> dict:
    """Inverse of :func:`split_tt`: overlay the TT subtree onto the frozen
    rest, reconstructing the full parameter tree."""
    def walk(t: dict, r: dict) -> dict:
        out = dict(r)
        for k, v in t.items():
            out[k] = v if k == "tt" else walk(v, r.get(k, {}))
        return out
    return walk(tt, rest)


def count_tt_params(params: dict) -> int:
    tt, _ = split_tt(params)
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tt))


# ---------------------------------------------------------------------------
# Decompose-init: start the TT twin at the rank-r optimum of the dense net
# ---------------------------------------------------------------------------

def tt_params_from_dense(tt_params: dict, dense_params: dict,
                         plans: dict | None = None) -> dict:
    """Replace every randomly-initialized TT core bundle in ``tt_params``
    with the TT-SVD of the matching dense weight from ``dense_params``
    (same tree minus the factorization).  Leaves with no dense
    counterpart are kept as-is.

    Dense linear storage is ``w [N_in, M_out]`` applied as ``y = x @ w``,
    while TT cores implement ``y = W x`` with ``W [M, N] = wᵀ`` — the
    transpose below is that convention bridge.  Stacked layers (scan
    groups, leading axes on ``w``) are decomposed per slice, exactly how
    the scan machinery slices the cores back out."""
    def walk(t_node, d_node):
        if not isinstance(t_node, dict):
            return t_node
        out = {}
        for k, v in t_node.items():
            if (k == "tt" and isinstance(v, dict)
                    and isinstance(d_node, dict) and "w" in d_node):
                out[k] = _decompose_bundle(v, d_node["w"])
            elif isinstance(v, dict) and isinstance(d_node, dict):
                out[k] = walk(v, d_node.get(k, {}))
            else:
                out[k] = v
        return out
    return walk(tt_params, dense_params)


def _decompose_bundle(bundle: dict, w) -> dict:
    d = sum(1 for k in bundle if k.startswith("c"))
    shapes = [bundle[f"c{t}"].shape for t in range(d)]
    core_shapes = [s[-4:] for s in shapes]
    stack = shapes[0][:-4]
    ns = tuple(int(s[1]) for s in core_shapes)
    ms = tuple(int(s[2]) for s in core_shapes)
    ranks = tuple([1] + [int(s[3]) for s in core_shapes[:-1]] + [1])
    plan = TTPlan(ms, ns, ranks)
    w_np = np.asarray(jax.device_get(w), np.float64)
    w_flat = w_np.reshape((-1,) + w_np.shape[len(stack):])
    per_slice = [tt_decompose(w_flat[i].T, plan)
                 for i in range(w_flat.shape[0])]
    out = {}
    for t in range(d):
        stacked = np.stack([sl[t] for sl in per_slice], axis=0)
        tgt = bundle[f"c{t}"]
        out[f"c{t}"] = jnp.asarray(
            stacked.reshape(stack + stacked.shape[1:]), tgt.dtype)
    for k, v in bundle.items():
        if not k.startswith("c"):
            out[k] = v
    return out


# ---------------------------------------------------------------------------
# The finetune loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FinetuneConfig:
    steps: int = 16
    opt: OptConfig = OptConfig(lr=3e-3, warmup_steps=2, total_steps=16,
                               weight_decay=0.0)


def make_tt_finetune_step(model: Model, opt_cfg: OptConfig):
    """Returns ``step(tt_params, opt, frozen, batch) → (tt_params, opt,
    metrics)``.  Gradients are taken w.r.t. the TT subtree only; the
    frozen backbone enters ``loss`` as a constant, so neither gradients
    nor optimizer state (nor AdamW decay) ever touch it."""
    def loss_fn(tt_params, frozen, batch):
        return model.loss(merge_tt(tt_params, frozen), batch, remat=False)

    def step(tt_params, opt, frozen, batch):
        loss, grads = jax.value_and_grad(loss_fn)(tt_params, frozen, batch)
        new_tt, new_opt, metrics = adamw_update(grads, opt, tt_params,
                                                opt_cfg)
        metrics["loss"] = loss
        return new_tt, new_opt, metrics

    return step


def finetune_tt(model: Model, params: dict, batches: list[dict],
                fcfg: FinetuneConfig = FinetuneConfig()
                ) -> tuple[dict, list[float]]:
    """Short rank-adaptive finetune of the TT cores (backbone frozen).

    Cycles deterministically through ``batches`` for ``fcfg.steps`` steps.
    Returns (params with finetuned cores, per-step loss history).  Raises
    ValueError if the tree has no TT bundles — a silent no-op here would
    let the study count a dense model as 'finetuned'."""
    tt_params, frozen = split_tt(params)
    if not jax.tree.leaves(tt_params):
        raise ValueError("finetune_tt: parameter tree has no TT core "
                         "bundles — nothing to finetune")
    # the jitted step donates its tt/opt inputs (in-place updates across
    # steps); copy first so the caller's ``params`` buffers stay alive
    tt_params = jax.tree.map(jnp.copy, tt_params)
    opt = adamw_init(tt_params)
    step = jax.jit(make_tt_finetune_step(model, fcfg.opt),
                   donate_argnums=(0, 1))
    history: list[float] = []
    for i in range(fcfg.steps):
        tt_params, opt, metrics = step(tt_params, opt, frozen,
                                       batches[i % len(batches)])
        history.append(float(metrics["loss"]))
    return merge_tt(tt_params, frozen), history
