"""Fault tolerance for 1000+-node runs.

Cluster runbook (how the pieces compose at scale):

1. **Checkpoint/restart** — ``CheckpointManager`` saves atomically every
   ``save_every`` steps (plus on SIGTERM, the standard preemption signal);
   a restarted job calls ``restore_or_init`` and resumes from the latest
   complete checkpoint, including the data-iterator cursor, so the token
   stream is bit-identical to an uninterrupted run.

2. **Node failure** — JAX SPMD jobs fail collectively: any chip loss kills
   the step. Recovery = restart on the surviving slice via the elastic path
   below. Checkpoints are multi-tier: every-N-steps to persistent store,
   optional every-step in-memory copy on neighbor hosts (not simulated
   here; the restore path is identical).

3. **Elastic rescale** — ``restore_or_init(..., mesh=new_mesh)``: leaves are
   loaded and device_put with shardings computed for the *new* mesh; GSPMD
   never bakes the mesh into the checkpoint (host numpy arrays), so DP/FSDP
   degree can change between runs. Verified in tests/test_fault.py.

4. **Straggler mitigation** — data shards are a pure function of
   (step, shard_id, num_shards) (data/pipeline.py), so work can be
   re-assigned without coordination; slow hosts never own unique state.
   Within a step, stragglers are absorbed by the collective schedule
   (bounded skew), beyond it by preemption+restart.
"""
from __future__ import annotations

import os
import signal

import jax

from . import checkpoint
from repro.distributed.sharding import param_shardings


class CheckpointManager:
    def __init__(self, path: str, save_every: int = 100, keep: int = 3):
        self.path = path
        self.save_every = save_every
        self.keep = keep
        self._preempted = False
        os.makedirs(path, exist_ok=True)

    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    @property
    def preempted(self) -> bool:
        """True after SIGTERM — the step loop should save and EXIT (a
        preempted job that keeps training races its own replacement)."""
        return self._preempted

    def should_save(self, step: int) -> bool:
        return self._preempted or (step > 0 and step % self.save_every == 0)

    def save(self, state: dict, step: int, data_state: dict | None = None):
        if jax.process_index() != 0:
            return
        checkpoint.save(self.path, state, step,
                        extra={"data_state": data_state or {}})
        checkpoint.prune(self.path, self.keep)

    def latest_step(self) -> int | None:
        steps = checkpoint.available_steps(self.path)
        return steps[-1] if steps else None

    def restore(self, template, shardings=None):
        return checkpoint.restore(self.path, template, shardings=shardings)


def restore_or_init(mgr: CheckpointManager, init_fn, template,
                    shardings=None):
    """Resume from the latest checkpoint if present, else initialize fresh.

    ``shardings``: optional tree (matching ``template``) of NamedShardings
    for the *current* mesh — restoring onto a different mesh than the one
    that saved is the elastic-rescale path.

    Returns (state, start_step, data_state).
    """
    if mgr.latest_step() is not None:
        state, manifest = mgr.restore(template, shardings)
        return state, manifest["step"], manifest["extra"].get("data_state", {})
    return init_fn(), 0, {}
