"""AdamW + cosine schedule, pure JAX, sharded states.

Optimizer moments inherit the parameters' sharding (FSDP'd in train mode),
so ZeRO-1 falls out of the GSPMD partitioning rather than bespoke code.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat, vhat = m / bc1, v / bc2
        new_p = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {"m": jax.tree.unflatten(treedef, [o[1] for o in out]),
                 "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
